"""Graph-level Program API: trace → compile-once → execute.

Eager registry dispatch (``api.use_backend`` + one kernel per call) lowers
every call in isolation and, on the ``pimsab`` backend, round-trips every
intermediate through DRAM.  This module adds the opt-in fast path:

* :func:`trace` wraps a function of registry-kernel calls; calling the traced
  function captures the kernel calls into a :class:`Program` — a small
  dataflow **DAG** over slots / captured constants / node outputs.  Values
  may fan out to any number of consumers (a residual-block input feeds both
  the conv path and the shortcut), kernels may fan in node-valued operands
  (residual adds), and any subset of values can be returned as program
  outputs; node order is trace order, which is topological by construction.
* :func:`compile_program` (exported as ``api.compile``) lowers a Program for
  the active backend **once** and returns a cached :class:`Executor`:

  - ``xla``/``interpret``/``pallas`` — the whole chain replays inside a
    single ``jax.jit``, so repeated calls never re-trace;
  - ``pimsab`` — the chain becomes one ``tensor_dsl.WorkloadGraph`` and is
    distributed/allocated/codegen'd jointly (``pimsab_backend``): integer
    producer→consumer intermediates stay CRAM-resident and the DRAM
    store/load pair at the kernel boundary is elided.

* The compile cache is keyed on the program signature (kernel names, operand
  shapes/dtypes, kwargs such as ``slice_bits``/``skip``, captured-constant
  fingerprints) plus the backend and — for pimsab — the functional machine
  config.  :func:`compile_cache_info` exposes hit/miss/size counters so
  "second compile was a cache hit" is assertable; :func:`cached_executable`
  shares the same cache with coarser consumers (the serve engine's
  prefill/decode steps).

Precision note: eager pimsab lowering sizes integer operands from their
*values* (per-call calibration); program mode must replay with fresh values,
so it sizes them from the *dtype* — results stay bit-exact, modeled cycles
differ slightly.
"""
from __future__ import annotations

import contextvars
import hashlib
import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = [
    "TraceError",
    "ProgramValue",
    "OpCall",
    "Program",
    "TracedFunction",
    "trace",
    "ResidentState",
    "Executor",
    "compile_program",
    "compile_cache_info",
    "clear_compile_cache",
    "cached_executable",
    "CacheInfo",
]


class TraceError(TypeError):
    """A traced function did something the Program IR cannot capture."""


# ---------------------------------------------------------------------------
# IR
# ---------------------------------------------------------------------------

# input references: ("slot", i) — i-th leaf of the call arguments;
# ("node", i) — output of the i-th captured kernel call;
# ("const", i) — array captured from the traced function's closure.
InRef = Tuple[str, int]


@dataclass(frozen=True)
class OpCall:
    """One captured registry-kernel call."""

    kernel: str
    inputs: Tuple[InRef, ...]
    kwargs: Tuple[Tuple[str, Any], ...]
    pallas_kwargs: Tuple[Tuple[str, Any], ...]
    out_aval: Tuple[Tuple[int, ...], str]  # (shape, dtype)


@dataclass(frozen=True)
class Program:
    """A traced sequence of registry kernel calls (the compile unit)."""

    name: str
    ops: Tuple[OpCall, ...]
    n_slots: int
    slot_avals: Tuple[Tuple[Tuple[int, ...], str], ...]
    consts: Tuple[np.ndarray, ...]
    in_tree: Any  # jax PyTreeDef of (args, kwargs)
    out_tree: Any
    out_refs: Tuple[InRef, ...]

    @property
    def kernels(self) -> Tuple[str, ...]:
        return tuple(op.kernel for op in self.ops)

    def signature(self) -> Tuple:
        """Hashable compile key: everything lowering depends on except the
        slot *values* — ops, slot avals, both pytree structures, the output
        refs (programs differing only in what they return must not share an
        Executor), and a content fingerprint per captured constant (their
        values are baked into the executor).  Memoized: constant hashing is
        paid once per Program, not per compile lookup."""
        sig = getattr(self, "_signature_cache", None)
        if sig is None:
            const_fp = tuple(
                (c.shape, str(c.dtype), hashlib.sha1(np.ascontiguousarray(c)).hexdigest())
                for c in self.consts
            )
            sig = (self.name, self.ops, self.slot_avals, self.in_tree,
                   self.out_tree, self.out_refs, const_fp)
            object.__setattr__(self, "_signature_cache", sig)
        return sig


class ProgramValue:
    """Placeholder for a kernel output inside :func:`trace`.

    It can only be passed to another registry kernel; any other use (jnp
    arithmetic, ``astype``, materialization) raises :class:`TraceError` with
    the capture position, so failures are early and named.
    """

    def __init__(self, node: int, aval: Tuple[Tuple[int, ...], str], kernel: str):
        self._node = node
        self._aval = aval
        self._kernel = kernel

    @property
    def shape(self) -> Tuple[int, ...]:
        return self._aval[0]

    @property
    def dtype(self):
        return np.dtype(self._aval[1])

    @property
    def ndim(self) -> int:
        return len(self._aval[0])

    def _refuse(self, what: str):
        raise TraceError(
            f"the output of kernel {self._kernel!r} (node {self._node}) is a "
            f"program-trace placeholder and does not support {what}; inside "
            "api.trace(...) kernel outputs can only feed other registry "
            "kernels (or be returned). Compute everything else outside the "
            "traced function."
        )

    def __array__(self, *a, **k):
        self._refuse("materialization")

    def __getattr__(self, name):
        raise TraceError(
            f"the output of kernel {self._kernel!r} (node {self._node}) is a "
            f"program-trace placeholder (no attribute {name!r}); inside "
            "api.trace(...) kernel outputs can only feed other registry "
            "kernels or be returned."
        )

    for _op in ("add", "radd", "sub", "rsub", "mul", "rmul", "truediv",
                "rtruediv", "matmul", "neg", "lt", "le", "gt", "ge"):
        exec(  # noqa: S102 - tiny metaprogram, keeps the refusal list in one place
            f"def __{_op}__(self, *a): self._refuse('arithmetic (__{_op}__)')"
        )
    del _op


def _aval_of(x: Any) -> Tuple[Tuple[int, ...], str]:
    if isinstance(x, ProgramValue):
        return x._aval
    a = np.asarray(x) if not hasattr(x, "dtype") else x
    return (tuple(a.shape), str(a.dtype))


# ---------------------------------------------------------------------------
# tracing
# ---------------------------------------------------------------------------


class _TraceCtx:
    def __init__(self, name: str, leaves: List[Any]):
        self.name = name
        self.slots_by_id = {id(l): i for i, l in enumerate(leaves)}
        self.slot_avals = tuple(_aval_of(l) for l in leaves)
        self.ops: List[OpCall] = []
        self.consts: List[Any] = []  # original objects (keeps ids alive)
        self.consts_by_id: Dict[int, int] = {}

    def _ref(self, a: Any) -> InRef:
        if isinstance(a, ProgramValue):
            return ("node", a._node)
        aid = id(a)
        if aid in self.slots_by_id:
            return ("slot", self.slots_by_id[aid])
        if aid not in self.consts_by_id:
            self.consts_by_id[aid] = len(self.consts)
            self.consts.append(a)
        return ("const", self.consts_by_id[aid])

    @staticmethod
    def _freeze_kwargs(kw: Optional[Dict[str, Any]]) -> Tuple[Tuple[str, Any], ...]:
        items = tuple(sorted((kw or {}).items()))
        try:
            hash(items)
        except TypeError:
            raise TraceError(
                f"kernel kwargs {kw!r} are not hashable — program signatures "
                "require static (hashable) kwargs"
            ) from None
        return items

    def record(self, kernel: str, args: Tuple[Any, ...], kwargs: Dict[str, Any],
               pallas_kwargs: Optional[Dict[str, Any]]) -> ProgramValue:
        from repro.kernels import api

        refs = tuple(self._ref(a) for a in args)
        # stand-ins for shape inference (node refs use the recorded aval)
        structs = []
        for (kind, i), a in zip(refs, args):
            shp, dt = self.ops[i].out_aval if kind == "node" else _aval_of(a)
            structs.append(jax.ShapeDtypeStruct(shp, np.dtype(dt)))
        oracle = api.get_kernel(kernel).oracle
        out = jax.eval_shape(lambda *xs: oracle(*xs, **(kwargs or {})), *structs)
        self.ops.append(OpCall(
            kernel=kernel,
            inputs=refs,
            kwargs=self._freeze_kwargs(kwargs),
            pallas_kwargs=self._freeze_kwargs(pallas_kwargs),
            out_aval=(tuple(out.shape), str(out.dtype)),
        ))
        return ProgramValue(len(self.ops) - 1, (tuple(out.shape), str(out.dtype)), kernel)


_trace_ctx: contextvars.ContextVar[Optional[_TraceCtx]] = contextvars.ContextVar(
    "repro_program_trace_ctx", default=None
)


def active_trace() -> Optional[_TraceCtx]:
    """The trace context ``api.dispatch`` must record into (None = eager)."""
    return _trace_ctx.get()


class TracedFunction:
    """``trace(fn)`` wrapper: call it like ``fn`` — each distinct input
    signature is traced once, compiled once (per backend), then replayed."""

    def __init__(self, fn: Callable[..., Any], name: Optional[str] = None):
        self.fn = fn
        self.name = name or getattr(fn, "__name__", "program")
        self._programs: Dict[Tuple, Program] = {}
        self._lock = threading.Lock()

    def trace(self, *args, **kwargs) -> Program:
        """Capture a fresh Program for these arguments (no caching)."""
        leaves, in_tree = jax.tree_util.tree_flatten((args, kwargs))
        return self._trace(leaves, in_tree, args, kwargs)

    def _trace(self, leaves, in_tree, args, kwargs) -> Program:
        ctx = _TraceCtx(self.name, leaves)
        token = _trace_ctx.set(ctx)
        try:
            result = self.fn(*args, **kwargs)
        finally:
            _trace_ctx.reset(token)
        if not ctx.ops:
            raise TraceError(
                f"trace({self.name}) captured no registry kernel calls — "
                "nothing to compile; call kernels via repro.kernels.api"
            )
        out_leaves, out_tree = jax.tree_util.tree_flatten(result)
        out_refs = tuple(ctx._ref(l) for l in out_leaves)
        return Program(
            name=self.name,
            ops=tuple(ctx.ops),
            n_slots=len(leaves),
            slot_avals=ctx.slot_avals,
            consts=tuple(np.asarray(c) for c in ctx.consts),
            in_tree=in_tree,
            out_tree=out_tree,
            out_refs=out_refs,
        )

    def program_for(self, *args, **kwargs) -> Program:
        """The (cached) Program this call signature maps to.

        The per-signature trace cache assumes captured constants (closure
        arrays) are stable; use this for introspection or when you own that
        guarantee — ``__call__`` re-traces instead, so it never replays stale
        constants.
        """
        leaves, in_tree = jax.tree_util.tree_flatten((args, kwargs))
        key = (in_tree, tuple(_aval_of(l) for l in leaves))
        with self._lock:
            prog = self._programs.get(key)
        if prog is None:
            prog = self._trace(leaves, in_tree, args, kwargs)
            with self._lock:
                prog = self._programs.setdefault(key, prog)
        return prog

    def __call__(self, *args, **kwargs):
        # Re-trace on every call: capture is cheap (one eval_shape per
        # kernel) and it keeps captured constants honest — an array computed
        # *from the arguments* inside fn is frozen into the program as a
        # constant, so replaying a cached trace would silently reuse the old
        # value.  Fresh constants change the signature's content fingerprint,
        # which routes to a correct (re)compile instead; only the expensive
        # lowering is cached.
        prog = self.trace(*args, **kwargs)
        ex = compile_program(prog)
        leaves, _ = jax.tree_util.tree_flatten((args, kwargs))
        return ex._execute_leaves(leaves)


def trace(fn: Callable[..., Any], *, name: Optional[str] = None) -> TracedFunction:
    """Wrap ``fn`` (a chain of ``repro.kernels.api`` kernel calls) so each
    call signature is captured once and executed through a cached, compiled
    :class:`Executor` on the backend active at call time."""
    return TracedFunction(fn, name=name)


# ---------------------------------------------------------------------------
# executors + compile cache
# ---------------------------------------------------------------------------


class ResidentState:
    """A persistent integer tensor the pimsab backend keeps CRAM-resident
    across program executions — the serve engine's KV cache.

    The handle names a ``(rows, fields)`` array stored at ``prec`` bits per
    field.  Bind it to a traced program's slot via
    ``compile_program(prog, states={slot_index: handle})``: the compiler
    reserves a wordline region for it, pins the slot's ``kv_append`` updater
    to that region (in_a and out alias — the append updates CRAM in place,
    zero DRAM traffic for the cache), and the executor seeds/harvests the
    region around each run.  ``.value`` always mirrors the logical cache
    after the most recent execution, so host-side swapping (the continuous-
    batching scheduler parking an evicted request's cache) is just reading
    and reassigning ``.value``.

    The slot still takes an aval-matching argument at call time — pass
    :meth:`placeholder`; its contents are ignored for state-bound slots.
    When the mapping layer *declines* residency (capacity or cost-model
    gated, see the compile's N-PLAN notes), execution transparently falls
    back to streaming ``.value`` through DRAM — same results, no silent
    wrong answers."""

    def __init__(self, name: str, shape: Tuple[int, int], prec: int,
                 dtype: str = "int8", init: Optional[np.ndarray] = None):
        if len(shape) != 2:
            raise ValueError(f"ResidentState {name!r} must be 2-D (rows, fields)")
        self.name = str(name)
        self.shape = (int(shape[0]), int(shape[1]))
        self.prec = int(prec)
        self.dtype = np.dtype(dtype)
        self.value = (
            np.zeros(self.shape, np.int64) if init is None
            else np.asarray(init, np.int64).copy()
        )
        if self.value.shape != self.shape:
            raise ValueError(
                f"ResidentState {name!r} init shape {self.value.shape} != {self.shape}"
            )

    def spec(self) -> Tuple[str, Tuple[int, int], int]:
        """The hashable compile-key identity: (name, shape, prec)."""
        return (self.name, self.shape, self.prec)

    def placeholder(self) -> np.ndarray:
        """An aval-matching argument for the state's slot — the compiled
        program reads the CRAM-resident value, never this array."""
        return np.zeros(self.shape, self.dtype)

    def to_array(self) -> np.ndarray:
        """The logical cache at its declared dtype (a copy)."""
        return self.value.astype(self.dtype)

    def __repr__(self) -> str:
        return (f"ResidentState({self.name!r}, shape={self.shape}, "
                f"prec={self.prec})")


@dataclass(frozen=True)
class CacheInfo:
    """Compile-cache counters plus one metadata record per cached Executor.

    Each entry is ``{"name", "backend", "kernels", "verify"}`` where
    ``verify`` summarizes the static-verifier outcome of that compile —
    error/warning counts and the ``N-PLAN`` notes explaining why
    ``distribute_graph`` declined residency or double buffering for the
    cached plan (``None`` when the compile skipped verification)."""

    hits: int
    misses: int
    size: int
    entries: Tuple[Dict[str, Any], ...] = ()


class Executor:
    """A compiled Program bound to one backend.  Call it with the same
    argument structure the traced function took; re-lowering never happens
    (``jax.jit`` replay for the TPU-side backends, a fused
    ``WorkloadGraph`` program for pimsab)."""

    def __init__(self, program: Program, backend: str,
                 run: Callable[[List[Any]], Any],
                 report: Optional[Any] = None,
                 verify_reports: Tuple[Any, ...] = ()):
        self.program = program
        self.backend = backend
        self._run = run
        self.report = report  # aggregated SimReport (pimsab), else None
        self.verify_reports = verify_reports  # VerifyReports (pimsab verify=True)
        self.states: Optional[Dict[int, "ResidentState"]] = None

    def bind_states(self, states: Dict[int, "ResidentState"]) -> None:
        """Swap in the ResidentState handles the next calls seed/harvest.

        The compiled artifact is keyed on state *specs*, not handles, so one
        executor serves many requests: the continuous-batching scheduler
        rebinds each request's caches before its decode step (spec-
        compatible handles only — the executor validates at run time)."""
        self.states = dict(states)

    def __call__(self, *args, **kwargs):
        leaves, in_tree = jax.tree_util.tree_flatten((args, kwargs))
        if in_tree != self.program.in_tree:
            raise TypeError(
                f"Executor({self.program.name!r}) called with a different "
                f"argument structure than it was traced with:\n"
                f"  traced: {self.program.in_tree}\n  got:    {in_tree}"
            )
        avals = tuple(_aval_of(l) for l in leaves)
        if avals != self.program.slot_avals:
            diffs = [
                f"  leaf {i}: traced {t}, got {g}"
                for i, (t, g) in enumerate(zip(self.program.slot_avals, avals))
                if t != g
            ]
            raise TypeError(
                f"Executor({self.program.name!r}) called with different leaf "
                "shapes/dtypes than it was compiled for (compile a new "
                "program for this signature):\n" + "\n".join(diffs)
            )
        return self._execute_leaves(leaves)

    def _execute_leaves(self, leaves: List[Any]):
        out_leaves = self._run(leaves)
        return jax.tree_util.tree_unflatten(self.program.out_tree, out_leaves)


_cache_lock = threading.Lock()
_cache: Dict[Any, Any] = {}
_cache_meta: Dict[Any, Dict[str, Any]] = {}
_hits = 0
_misses = 0


def compile_cache_info() -> CacheInfo:
    """Hit/miss/size counters of the global compile cache (Executors + other
    cached executables such as serve steps), plus per-entry metadata — the
    structured verifier summary recorded at compile time, including the
    plan-decline notes (see :class:`CacheInfo`)."""
    with _cache_lock:
        return CacheInfo(
            hits=_hits, misses=_misses, size=len(_cache),
            entries=tuple(dict(m) for m in _cache_meta.values()),
        )


def clear_compile_cache() -> None:
    """Empty the global compile cache and reset its hit/miss counters (test
    isolation; compiled Executors are rebuilt on next use)."""
    global _hits, _misses
    with _cache_lock:
        _cache.clear()
        _cache_meta.clear()
        _hits = 0
        _misses = 0


def cached_executable(key: Any, build: Callable[[], Any],
                      meta: Optional[Callable[[Any], Dict[str, Any]]] = None) -> Any:
    """Generic compile-once: return the cached artifact for ``key`` or build
    it (outside the lock — builds can be slow and re-entrant).  ``meta``, if
    given, maps the freshly built artifact to the :class:`CacheInfo` entry
    recorded for it."""
    global _hits, _misses
    with _cache_lock:
        if key in _cache:
            _hits += 1
            return _cache[key]
    artifact = build()
    with _cache_lock:
        if key in _cache:  # lost a race: keep the first, still a miss for us
            _misses += 1
            return _cache[key]
        _misses += 1
        _cache[key] = artifact
        if meta is not None:
            _cache_meta[key] = meta(artifact)
    return artifact


def _jax_run(program: Program, backend: str) -> Callable[[List[Any]], Any]:
    """Replay the whole program inside one jitted function (compile-once for
    the jax-side backends)."""
    from repro.kernels import api

    def replay(leaves, consts):
        env: Dict[int, Any] = {}

        def resolve(ref):
            kind, i = ref
            if kind == "slot":
                return leaves[i]
            if kind == "const":
                return consts[i]
            return env[i]

        with api.use_backend(backend):
            for idx, op in enumerate(program.ops):
                vals = [resolve(r) for r in op.inputs]
                env[idx] = api.dispatch(
                    op.kernel, *vals,
                    pallas_kwargs=dict(op.pallas_kwargs) or None,
                    **dict(op.kwargs),
                )
        return [resolve(r) for r in program.out_refs]

    jitted = jax.jit(replay)
    consts = [np.asarray(c) for c in program.consts]
    return lambda leaves: jitted(leaves, consts)


def _executor_meta(ex: "Executor") -> Dict[str, Any]:
    """The :class:`CacheInfo` entry for a freshly compiled Executor: identity
    plus the static-verifier summary (error/warning counts and the N-PLAN
    notes recording why residency/double-buffering was declined)."""
    entry: Dict[str, Any] = {
        "name": ex.program.name,
        "backend": ex.backend,
        "kernels": list(ex.program.kernels),
        "verify": None,
    }
    if ex.verify_reports:
        entry["verify"] = {
            "ok": all(r.ok for r in ex.verify_reports),
            "errors": sum(len(r.errors) for r in ex.verify_reports),
            "warnings": sum(len(r.warnings) for r in ex.verify_reports),
            "notes": sorted({
                (d.node, d.message)
                for r in ex.verify_reports for d in r.notes
            }),
        }
    if ex.report is not None and getattr(ex.report, "autotune", None):
        entry["autotune"] = dict(ex.report.autotune)
    return entry


def compile_program(program: Program, backend: Optional[str] = None, *,
                    verify: bool = True,
                    states: Optional[Dict[int, ResidentState]] = None,
                    tune: Any = None,
                    chips: Optional[int] = None,
                    cluster: Any = None,
                    plan: str = "auto") -> Executor:
    """Lower ``program`` for ``backend`` (default: the active backend) and
    return the Executor — cached on (signature, backend[, machine config,
    verify]), so an identical second compile is a pure cache hit.

    ``verify=True`` (the default) runs the compile-time static verifier on
    the pimsab backend — liveness/def-use, schedule-hazard race detection
    and precision-overflow lint over both fused ISA streams — raising
    :class:`repro.core.compiler.verify.VerifierError` on any error; the
    verifier summary (including plan-decline notes) is recorded on the cache
    entry, visible via :func:`compile_cache_info`.  The flag is a no-op on
    the jax-side backends.

    ``states`` (pimsab only) maps slot index → :class:`ResidentState`: the
    slot's KV cache stays CRAM-resident across calls.  The cache key carries
    the state *specs*, so spec-identical handles share one executor — use
    :meth:`Executor.bind_states` (done here automatically) to swap handles
    between calls.

    ``tune`` (pimsab only) opts the timing-side lowering into the mapping
    autotuner: ``True`` uses the default :class:`~repro.core.compiler.
    autotune.TuneConfig`, an explicit ``TuneConfig`` pins the search budget
    and seed, ``False`` forces it off, and ``None`` (the default) inherits
    an enclosing :func:`repro.kernels.api.tuning` scope.  The effective
    config joins the cache key, so tuned and untuned executors for the same
    program coexist, and the winning search provenance is recorded on the
    cache entry (``compile_cache_info().entries[...]["autotune"]``).

    ``chips``/``cluster`` (pimsab only) compile the program for a multi-chip
    :class:`~repro.core.noc.ChipCluster` instead of one chip: the returned
    :class:`~repro.kernels.multichip.ClusterExecutor` runs the sharded plan
    bit-exactly against the 1-chip result.  ``plan`` forces ``"tp"``/``"pp"``
    or leaves the cost model to choose (``"auto"``, the default)."""
    from repro.kernels import api

    backend = api._check_backend(backend or api.current_backend())
    if cluster is not None or (chips is not None and int(chips) != 1):
        # Multi-chip scale-out: shard the program across a ChipCluster and
        # return the bit-exact ClusterExecutor (repro.kernels.multichip).
        if backend != "pimsab":
            raise NotImplementedError(
                "chips/cluster sharding is a pimsab-backend concept; the "
                "jax-side backends replay the whole program on one device"
            )
        if states:
            raise NotImplementedError(
                "ResidentState stays CRAM-resident on one chip and does not "
                "shard across a ChipCluster; serve on chips=1"
            )
        from repro.kernels import multichip

        return multichip.compile_cluster(
            program, chips=chips, cluster=cluster,
            plan=plan, verify=verify, tune=tune,
        )
    key: Tuple = ("program", program.signature(), backend)
    if backend == "pimsab":
        from repro.core.compiler import autotune
        from repro.kernels import pimsab_backend as pb

        tc = autotune.resolve(tune) if tune is not None else autotune.active()
        state_specs = tuple(sorted(
            (slot, st.spec()) for slot, st in (states or {}).items()
        ))
        key = key + (pb._functional_cfg(), bool(verify), state_specs, tc)

        def build() -> Executor:
            compiled = pb.compile_traced_program(
                program, verify=verify,
                state_slots={slot: st.spec() for slot, st in states.items()}
                if states else None,
                tune=tc if tc is not None else False,
            )
            ex = Executor(
                program, backend,
                run=None,  # set below: the closure reads ex.states per call
                report=compiled.report,
                verify_reports=compiled.verify_reports,
            )
            ex._run = lambda leaves: pb.execute_traced_program(
                compiled, leaves, states=ex.states
            )
            return ex
    else:
        if states:
            raise NotImplementedError(
                "ResidentState is a pimsab-backend concept; the jax-side "
                "backends replay the whole chain functionally"
            )

        def build() -> Executor:
            return Executor(program, backend, run=_jax_run(program, backend))

    ex = cached_executable(key, build, meta=_executor_meta)
    if states is not None:
        ex.bind_states(states)
    return ex
