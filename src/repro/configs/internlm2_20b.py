"""InternLM2-20B — dense GQA transformer [arXiv:2403.17297; hf]."""
from repro.configs.base import ModelConfig, QuantConfig

CONFIG = ModelConfig(
    name="internlm2-20b",
    family="dense",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92544,
    block_pattern=("attn",),
    rope_theta=1_000_000.0,
    quant=QuantConfig(enabled=True, act_bits=8, weight_bits=8),
    source="[arXiv:2403.17297; hf]",
)
