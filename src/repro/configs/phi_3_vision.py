"""Phi-3-Vision-4.2B — phi3-mini backbone + CLIP frontend STUB
[hf:microsoft/Phi-3-vision-128k-instruct; hf].

input_specs() provides precomputed patch embeddings (batch, 576, d_model);
they are fused into the first prompt positions.
"""
from repro.configs.base import ModelConfig, QuantConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    block_pattern=("attn",),
    frontend="vision",
    n_patches=576,
    quant=QuantConfig(enabled=True, act_bits=8, weight_bits=8),
    source="[hf:microsoft/Phi-3-vision-128k-instruct; hf]",
)
