"""Kimi-K2 1T-A32B — trillion-parameter MoE, 384 experts top-8 [arXiv:2501.kimi2; unverified].

Assignment specifies GQA kv=8 and per-expert d_ff=2048 (fine-grained experts).
"""
from repro.configs.base import ModelConfig, QuantConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=163840,
    block_pattern=("attn",),
    n_experts=384,
    experts_per_token=8,
    moe_capacity_factor=1.25,
    quant=QuantConfig(enabled=True, act_bits=8, weight_bits=8),
    source="[arXiv:2501.kimi2; unverified]",
)
