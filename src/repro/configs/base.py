"""Architecture + shape configuration for the PIMSAB-framework reproduction.

Every assigned architecture is a :class:`ModelConfig`; every input-shape cell is
a :class:`ShapeCell`.  The dry-run, trainer, server and smoke tests all consume
these — there is exactly one source of truth for each (arch × shape) cell.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Quantization (the paper's bit-serial-aware computation, TPU-native form)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class QuantConfig:
    """Bit-plane / bit-slice quantization config (PIMSAB adaptive precision).

    ``act_bits``/``weight_bits`` choose the integer precision of the bit-plane
    matmul path; ``slice_bits`` is the hardware-native slice width (8 on the
    TPU int8 MXU path — the radix-256 analogue of PIMSAB's 1-bit PEs).
    ``skip_zero_slices`` statically skips all-zero weight slices, the
    ``mul_const`` zero-bit-skipping optimization.
    """

    enabled: bool = False
    act_bits: int = 8
    weight_bits: int = 8
    slice_bits: int = 8
    skip_zero_slices: bool = True

    @property
    def act_slices(self) -> int:
        return max(1, math.ceil(self.act_bits / self.slice_bits))

    @property
    def weight_slices(self) -> int:
        return max(1, math.ceil(self.weight_bits / self.slice_bits))


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    """A transformer-family architecture.

    ``block_pattern`` is the repeating unit of layer kinds; it is tiled to
    ``n_layers``.  Recognized kinds:

    * ``"attn"``        — full (causal for decoders) GQA attention block
    * ``"local_attn"``  — windowed attention block (``window`` tokens)
    * ``"rglru"``       — RG-LRU recurrent block (RecurrentGemma)
    * ``"mlstm"``       — xLSTM matrix-memory block
    * ``"slstm"``       — xLSTM scalar-memory block
    """

    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 => d_model // n_heads
    qkv_bias: bool = False
    block_pattern: Tuple[str, ...] = ("attn",)
    window: int = 0  # local-attention window (tokens)
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    # --- encoder/decoder (whisper) ---
    n_enc_layers: int = 0  # >0 => encoder-decoder; n_layers is the decoder depth
    enc_seq_len: int = 1500  # whisper audio frames after conv frontend (stub)
    # --- modality frontend stubs ---
    frontend: Optional[str] = None  # "audio" | "vision"
    n_patches: int = 576  # vision stub: patch embeddings prepended to the prompt
    # --- misc ---
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # WSD (warmup-stable-decay) schedule flag — MiniCPM trains with it.
    wsd_schedule: bool = False
    # PIMSAB technique: bit-plane quantized matmuls for the big projections.
    quant: QuantConfig = field(default_factory=QuantConfig)
    # citation provenance [source; verified-tier]
    source: str = ""

    # ---- derived ----
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.resolved_head_dim

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def padded_vocab(self, multiple: int = 2048) -> int:
        """Vocab padded for clean TP sharding (MaxText practice)."""
        return ((self.vocab_size + multiple - 1) // multiple) * multiple

    @property
    def subquadratic(self) -> bool:
        """True if the arch never materializes full O(S^2) attention —
        required for the long_500k cell."""
        quadratic = {"attn"}
        return not any(k in quadratic for k in self.block_pattern)

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer kinds, the pattern tiled to n_layers."""
        reps = -(-self.n_layers // len(self.block_pattern))
        return (self.block_pattern * reps)[: self.n_layers]

    def pattern_groups(self) -> int:
        """Number of scan groups (n_layers / pattern length)."""
        if self.n_layers % len(self.block_pattern) != 0:
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not divisible by "
                f"pattern length {len(self.block_pattern)}"
            )
        return self.n_layers // len(self.block_pattern)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, hd = self.d_model, self.resolved_head_dim
        n = 0
        n += self.vocab_size * d  # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d  # lm head
        per_kind = {}
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.qkv_bias:
            attn += self.q_dim + 2 * self.kv_dim
        per_kind["attn"] = attn + 2 * d  # + norms
        per_kind["local_attn"] = per_kind["attn"]
        if self.is_moe:
            ffn = self.n_experts * 3 * d * self.d_ff + d * self.n_experts
        else:
            ffn = 3 * d * self.d_ff  # gated SwiGLU
        # rglru block: in/out proj (d->2*rnn_w, rnn_w->d), conv, gates
        rnn_w = max(d, 1)
        per_kind["rglru"] = 2 * d * rnn_w + rnn_w * d + 4 * rnn_w + 2 * d
        # mlstm: up-proj x2 (factor 2), qkv in projected space, down-proj
        pf = 2 * d
        per_kind["mlstm"] = 2 * d * pf + 3 * pf * pf // max(1, self.n_heads) + pf * d + 2 * d
        per_kind["slstm"] = 4 * d * d + 4 * d * (d // max(1, self.n_heads)) + 2 * d
        for kind in self.layer_kinds():
            n += per_kind.get(kind, 0)
            if kind in ("attn", "local_attn") and self.d_ff > 0:
                n += ffn + d  # ffn norm
        enc_layers = self.n_enc_layers
        if enc_layers:
            n += enc_layers * (per_kind["attn"] + ffn + d)
            n += self.n_layers * (per_kind["attn"])  # cross-attention
        return n

    def active_param_count(self) -> int:
        """Params touched per token (== param_count for dense)."""
        if not self.is_moe:
            return self.param_count()
        dense = self.param_count()
        all_experts = self.n_layers * self.n_experts * 3 * self.d_model * self.d_ff
        active = self.n_layers * self.experts_per_token * 3 * self.d_model * self.d_ff
        return dense - all_experts + active


# ---------------------------------------------------------------------------
# Input-shape cells
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", "train", 4_096, 256),
    ShapeCell("prefill_32k", "prefill", 32_768, 32),
    ShapeCell("decode_32k", "decode", 32_768, 128),
    ShapeCell("long_500k", "decode", 524_288, 1),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def cell_supported(cfg: ModelConfig, cell: ShapeCell) -> Tuple[bool, str]:
    """(supported, reason).  long_500k needs sub-quadratic attention."""
    if cell.name == "long_500k" and not cfg.subquadratic:
        return False, "skipped(full-attention): 500k dense-KV decode is not run for pure full-attention archs"
    return True, "ok"
