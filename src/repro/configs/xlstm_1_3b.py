"""xLSTM-1.3B — alternating mLSTM/sLSTM blocks [arXiv:2405.04517; unverified].

d_ff=0: blocks carry their own projections (mLSTM pre-up-projection ×2,
sLSTM post-up gated FFN).  Sub-quadratic: runs the long_500k cell.
"""
from repro.configs.base import ModelConfig, QuantConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_pattern=("mlstm", "mlstm", "mlstm", "mlstm", "mlstm", "mlstm", "slstm", "mlstm"),
    quant=QuantConfig(enabled=True, act_bits=8, weight_bits=8),
    source="[arXiv:2405.04517; unverified]",
)
