"""Granite-20B (code) — llama-arch, MQA (kv=1) [arXiv:2405.04324; hf]."""
from repro.configs.base import ModelConfig, QuantConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    block_pattern=("attn",),
    quant=QuantConfig(enabled=True, act_bits=8, weight_bits=8),
    source="[arXiv:2405.04324; hf]",
)
