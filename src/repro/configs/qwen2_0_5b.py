"""Qwen2-0.5B — dense GQA with QKV bias [arXiv:2407.10671; hf]."""
from repro.configs.base import ModelConfig, QuantConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151936,
    qkv_bias=True,
    block_pattern=("attn",),
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    quant=QuantConfig(enabled=True, act_bits=8, weight_bits=8),
    source="[arXiv:2407.10671; hf]",
)
