"""RecurrentGemma-2B — RG-LRU + local attention, 2:1 pattern [arXiv:2402.19427; hf].

Pattern is (rglru, rglru, local_attn) repeated; window 2048.  Sub-quadratic:
runs the long_500k cell.
"""
from repro.configs.base import ModelConfig, QuantConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,  # 26 residual blocks; pattern padded below
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab_size=256000,
    head_dim=256,
    # 26 = 8 full patterns of 3 + (rglru, rglru); we express the official
    # layout with a length-13 half-pattern repeated twice.
    block_pattern=("rglru", "rglru", "local_attn") * 4 + ("rglru",),
    window=2048,
    tie_embeddings=True,
    quant=QuantConfig(enabled=True, act_bits=8, weight_bits=8),
    source="[arXiv:2402.19427; hf]",
)
