"""Registry of assigned architectures and shape cells.

>>> from repro.configs import get_config, list_archs, SHAPES
>>> cfg = get_config("qwen2-0.5b")
>>> tiny = reduced_config(cfg)   # for CPU smoke tests
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.configs.base import (  # noqa: F401  (re-exported)
    ModelConfig,
    QuantConfig,
    ShapeCell,
    SHAPES,
    SHAPES_BY_NAME,
    cell_supported,
)

from repro.configs.internlm2_20b import CONFIG as _internlm2
from repro.configs.qwen2_0_5b import CONFIG as _qwen2
from repro.configs.granite_20b import CONFIG as _granite
from repro.configs.minicpm_2b import CONFIG as _minicpm
from repro.configs.recurrentgemma_2b import CONFIG as _rgemma
from repro.configs.kimi_k2_1t import CONFIG as _kimi
from repro.configs.dbrx_132b import CONFIG as _dbrx
from repro.configs.whisper_medium import CONFIG as _whisper
from repro.configs.xlstm_1_3b import CONFIG as _xlstm
from repro.configs.phi_3_vision import CONFIG as _phi3v

_REGISTRY: Dict[str, ModelConfig] = {
    c.name: c
    for c in (
        _internlm2,
        _qwen2,
        _granite,
        _minicpm,
        _rgemma,
        _kimi,
        _dbrx,
        _whisper,
        _xlstm,
        _phi3v,
    )
}


def list_archs() -> List[str]:
    return sorted(_REGISTRY)


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {list_archs()}")
    return _REGISTRY[name]


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests.

    Shrinks depth/width/experts/vocab but keeps the block pattern family,
    GQA ratio, bias/tie/frontend flags — i.e. everything that changes code
    paths — intact.
    """
    pat = tuple(dict.fromkeys(cfg.block_pattern))  # unique kinds, order kept
    # keep at least one of each kind; two pattern groups
    n_layers = 2 * len(pat)
    n_heads = min(cfg.n_heads, 4)
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    while n_heads % n_kv:
        n_kv -= 1
    head_dim = 16
    d_model = n_heads * head_dim * 2  # d_model != q_dim to exercise projections
    return dataclasses.replace(
        cfg,
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=0 if cfg.d_ff == 0 else 4 * head_dim,
        vocab_size=256,
        block_pattern=pat,
        window=min(cfg.window, 8) if cfg.window else 0,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        experts_per_token=min(cfg.experts_per_token, 2) if cfg.n_experts else 0,
        n_enc_layers=2 if cfg.n_enc_layers else 0,
        enc_seq_len=8,
        n_patches=4,
    )


SMOKE_SHAPE = ShapeCell("smoke", "train", 16, 2)
