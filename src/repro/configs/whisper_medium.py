"""Whisper-medium — encoder-decoder audio backbone [arXiv:2212.04356; unverified].

24 encoder + 24 decoder layers; the conv frontend is a STUB — input_specs()
supplies precomputed (batch, 1500, d_model) frame embeddings.
"""
from repro.configs.base import ModelConfig, QuantConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,  # decoder depth
    n_enc_layers=24,
    enc_seq_len=1500,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    block_pattern=("attn",),
    frontend="audio",
    quant=QuantConfig(enabled=True, act_bits=8, weight_bits=8),
    source="[arXiv:2212.04356; unverified]",
)
