"""MiniCPM-2B — llama-like dense (MHA: kv=36), WSD schedule [arXiv:2404.06395; hf]."""
from repro.configs.base import ModelConfig, QuantConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_ff=5760,
    vocab_size=122753,
    block_pattern=("attn",),
    tie_embeddings=True,
    wsd_schedule=True,
    quant=QuantConfig(enabled=True, act_bits=8, weight_bits=8),
    source="[arXiv:2404.06395; hf]",
)
