"""Training launcher CLI.

Tiny/smoke configs run real steps on this host; full configs on the
production mesh are launched the same way on a pod (the dry-run proves the
lowering).  ``--simulate-failure`` exercises the restart path end-to-end:
train, kill mid-run, relaunch, verify bit-exact continuation.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, reduced_config
from repro.data.pipeline import DataConfig
from repro.models.runtime import RunFlags
from repro.train.trainer import TrainLoopConfig, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", help="smoke-size config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--no-resume", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch)
    loop = TrainLoopConfig(
        steps=args.steps, ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir, base_lr=args.lr
    )
    out = train(cfg, data_cfg, loop, RunFlags(attn_chunk=64, flash_threshold=256), resume=not args.no_resume)
    for h in out["history"]:
        print(h)
    if out["resumed_from"] is not None:
        print(f"(resumed from step {out['resumed_from']})")


if __name__ == "__main__":
    main()
