"""Analytic per-device memory model for the dry-run.

The CPU backend's ``memory_analysis().temp_size_in_bytes`` is a *pessimistic*
bound: XLA-CPU's buffer assignment does not reuse rematerialized-region
buffers (we verified the remat recomputes ARE in the optimized HLO — 104 vs 72
tanh in the probe — so a TPU compile honors them; the CPU slab just co-lives
them).  This module computes what a lifetime-aware assignment needs:

* params / optimizer / cache / batch bytes — **exact**, from the sharded
  ShapeDtypeStruct trees (leaf bytes ÷ shard factor of its PartitionSpec);
* training activations — the remat-policy bound: one bf16 block-input
  checkpoint per layer + the logits/CE working set + one block's live
  working set.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCell
from repro.dist.sharding import MeshRules


def _shard_factor(spec, shape, mesh) -> int:
    if spec is None:
        return 1
    f = 1
    for entry in spec:
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        for ax in axes:
            f *= mesh.shape[ax]
    return f


def sharded_bytes(shapes_tree: Any, mesh) -> int:
    """Total per-device bytes of a ShapeDtypeStruct tree whose leaves carry
    NamedShardings (as produced by launch.specs.sharded_tree)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(shapes_tree):
        n = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        sh = getattr(leaf, "sharding", None)
        spec = getattr(sh, "spec", None)
        total += n // max(_shard_factor(spec, leaf.shape, mesh), 1)
    return total


def activation_bytes(cfg: ModelConfig, cell: ShapeCell, rules: MeshRules, flags) -> Dict[str, int]:
    """Remat-policy activation bound for one train step (per device)."""
    dp = rules.dp
    tokens_dev = cell.tokens // dp
    d = cfg.d_model
    # one bf16 checkpoint (the block input) per layer
    ckpt = cfg.n_layers * tokens_dev * d * 2
    if cfg.is_encdec:
        ckpt += cfg.n_enc_layers * (cell.global_batch // dp) * cfg.enc_seq_len * d * 2
    # logits + CE working set: bf16 logits, fp32 logsumexp chain, fp32 grad
    vp_dev = cfg.padded_vocab() // rules.tp
    logits = tokens_dev * vp_dev * (2 + 4 + 4)
    # one block's live working set during its backward (fp32-heavy)
    widths = [4 * d]  # attention qkv+proj working margin
    if cfg.d_ff:
        widths.append(2 * cfg.d_ff if not cfg.is_moe else 2 * cfg.d_ff)
    if "mlstm" in cfg.block_pattern:
        widths.append(8 * d)
    chunk_att = getattr(flags, "attn_chunk", 1024)
    att_scores = (cell.global_batch // dp) * cfg.n_heads * chunk_att * chunk_att * 4 * 3
    block_live = tokens_dev * max(widths) * 4 * 2 + att_scores
    return {
        "checkpoint_bytes": ckpt,
        "logits_bytes": logits,
        "block_live_bytes": block_live,
        "total": ckpt + logits + block_live,
    }


def analytic_memory(cfg, cell, rules, flags, specs: Dict[str, Any]) -> Dict[str, Any]:
    mesh = rules.mesh
    out: Dict[str, Any] = {}
    if cell.kind == "train":
        out["state_bytes_per_device"] = sharded_bytes(specs["state"], mesh)
        out["batch_bytes_per_device"] = sharded_bytes(specs["batch"], mesh)
        acts = activation_bytes(cfg, cell, rules, flags)
        out["activation_bytes_per_device"] = acts
        out["analytic_peak_per_device"] = (
            # state twice (in + out; donation would alias, we report undonated)
            out["state_bytes_per_device"]
            + out["batch_bytes_per_device"]
            + acts["total"]
        )
        out["fits_v5e_16g"] = bool(out["analytic_peak_per_device"] < 16 * 2**30)
    else:
        out["params_bytes_per_device"] = sharded_bytes(specs["params"], mesh)
        if "cache" in specs:
            out["cache_bytes_per_device"] = sharded_bytes(specs["cache"], mesh)
        if "batch" in specs:
            out["batch_bytes_per_device"] = sharded_bytes(specs["batch"], mesh)
        total = sum(v for v in out.values() if isinstance(v, int))
        # decode/prefill working set is small relative to weights+cache; add 10%
        out["analytic_peak_per_device"] = int(total * 1.1)
        out["fits_v5e_16g"] = bool(out["analytic_peak_per_device"] < 16 * 2**30)
    return out
