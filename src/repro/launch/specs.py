"""ShapeDtypeStruct input stand-ins for every (arch × shape × step) cell —
weak-type-correct, shardable, no device allocation.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCell
from repro.dist.sharding import MeshRules, cache_entry_spec, param_specs
from repro.models.runtime import RunFlags, DEFAULT_FLAGS


def _sds(shape, dtype, rules: Optional[MeshRules], spec: Optional[P]):
    if rules is None or spec is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(rules.mesh, spec))


def batch_specs(
    cfg: ModelConfig, cell: ShapeCell, rules: Optional[MeshRules] = None, with_labels: bool = True
) -> Dict[str, Any]:
    """The token batch (+ frontend stub embeddings) for train/prefill."""
    b, s = cell.global_batch, cell.seq_len
    axes = rules.batch_axes(b) if rules else None
    dt = jnp.dtype(cfg.dtype)
    out: Dict[str, Any] = {
        "tokens": _sds((b, s), jnp.int32, rules, P(axes, None) if rules else None)
    }
    if with_labels:
        out["labels"] = _sds((b, s), jnp.int32, rules, P(axes, None) if rules else None)
    if cfg.is_encdec:
        out["enc_embeds"] = _sds(
            (b, cfg.enc_seq_len, cfg.d_model), dt, rules, P(axes, None, None) if rules else None
        )
    if cfg.frontend == "vision":
        out["patch_embeds"] = _sds(
            (b, cfg.n_patches, cfg.d_model), dt, rules, P(axes, None, None) if rules else None
        )
    return out


def sharded_tree(shapes: Any, specs: Any, rules: Optional[MeshRules]) -> Any:
    """Attach NamedShardings to a ShapeDtypeStruct tree."""
    if rules is None:
        return shapes
    return jax.tree_util.tree_map(
        lambda sh, sp: jax.ShapeDtypeStruct(
            sh.shape, sh.dtype, sharding=NamedSharding(rules.mesh, sp)
        ),
        shapes,
        specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


def input_specs(
    cfg: ModelConfig,
    cell: ShapeCell,
    rules: Optional[MeshRules] = None,
    flags: RunFlags = DEFAULT_FLAGS,
) -> Dict[str, Any]:
    """All inputs for the cell's step function, as (sharded) SDS trees.

    train  → {"state": ..., "batch": ...}               for train_step
    prefill→ {"params": ..., "batch": ...}              for prefill
    decode → {"params": ..., "cache": ..., "tokens":..} for decode_step
    """
    from repro.serve.engine import cache_specs, serve_params_shape
    from repro.train.optimizer import AdamWConfig
    from repro.train.steps import train_state_shape, train_state_specs
    from repro.models.transformer import cache_shape

    if cell.kind == "train":
        opt_cfg = AdamWConfig()
        sshapes = train_state_shape(cfg, opt_cfg)
        sspecs = train_state_specs(cfg, rules, opt_cfg, flags) if rules else None
        state = sharded_tree(sshapes, sspecs, rules)
        return {"state": state, "batch": batch_specs(cfg, cell, rules, with_labels=True)}

    pshapes = serve_params_shape(cfg, flags)
    pspecs = param_specs(pshapes, cfg, rules) if rules else None
    params = sharded_tree(pshapes, pspecs, rules)
    if cell.kind == "prefill":
        return {"params": params, "batch": batch_specs(cfg, cell, rules, with_labels=False)}

    # decode: one new token against a cache of seq_len
    b = cell.global_batch
    cshapes = cache_shape(cfg, b, cell.seq_len, flags)
    cspecs = cache_specs(cfg, b, cell.seq_len, rules, flags) if rules else None
    cache = sharded_tree(cshapes, cspecs, rules)
    axes = rules.batch_axes(b) if rules else None
    tokens = _sds((b, 1), jnp.int32, rules, P(axes, None) if rules else None)
    return {"params": params, "cache": cache, "tokens": tokens}
