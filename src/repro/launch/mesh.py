"""Production mesh builders.

A function, not a module-level constant — importing this module never touches
jax device state.  Single pod: 16×16 = 256 chips ("data","model"); multi-pod:
2×16×16 = 512 chips ("pod","data","model").  The "model" axis is the intra-pod
H-tree analogue (reductions stay local); "pod" carries only data-parallel
traffic (PIMSAB's inter-tile rule: no cross-tile partial-sum reduction).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Whatever the current host offers (smoke tests / examples on CPU)."""
    n = len(jax.devices())
    model = max(1, min(model, n))
    return jax.make_mesh((n // model, model), ("data", "model"))


# TPU v5e hardware constants used by the roofline (per chip).
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW_PER_LINK = 50e9  # B/s per link (~ per-direction)
