"""Parse compiled (partitioned, per-device) HLO text for collective traffic,
and derive the three roofline terms.

cost_analysis() reports per-device FLOPs and bytes; collective bytes are NOT
in cost_analysis, so we scan the HLO for all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute ops and sum their *operand*
sizes.  In the partitioned module operand shapes are per-device; operands are
printed by name only, so operand bytes are reconstructed from the printed
output shape + op semantics + replica-group size:

  all-reduce:        operand == output
  all-gather:        operand == output / group
  reduce-scatter:    operand == output × group
  all-to-all:        operand == output
  collective-permute operand == output

The estimated wire time additionally applies ring-algorithm factors
(all-reduce moves 2(g-1)/g × payload per chip; gather/scatter (g-1)/g).
"""
from __future__ import annotations

import dataclasses
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16, "token": 0,
}

_COLL_RE = re.compile(
    r"=\s*(?P<shape>\(?[a-z0-9\[\],{}\s/#*_-]+?\)?)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
    re.IGNORECASE,
)
_SHAPE_RE = re.compile(r"(?P<dt>[a-z][a-z0-9]*)\[(?P<dims>[\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    counts: Dict[str, int] = field(default_factory=dict)
    operand_bytes: Dict[str, int] = field(default_factory=dict)
    wire_bytes: Dict[str, float] = field(default_factory=dict)

    @property
    def total_operand_bytes(self) -> int:
        return sum(self.operand_bytes.values())

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())

    def to_json(self) -> Dict:
        return {
            "counts": self.counts,
            "operand_bytes": self.operand_bytes,
            "wire_bytes": {k: float(v) for k, v in self.wire_bytes.items()},
            "total_operand_bytes": self.total_operand_bytes,
            "total_wire_bytes": float(self.total_wire_bytes),
        }


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))  # iota [groups, group_size]
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([t for t in m.group(1).split(",") if t.strip() != ""])
    return 2  # collective-permute etc: near-neighbour


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op").lower()
        out_bytes = _shape_bytes(m.group("shape"))
        g = _group_size(line)
        if op == "all-gather":
            operand = out_bytes // max(g, 1)
            wire = out_bytes * (g - 1) / max(g, 1)
        elif op == "reduce-scatter":
            operand = out_bytes * g
            wire = operand * (g - 1) / max(g, 1)
        elif op == "all-reduce":
            operand = out_bytes
            wire = 2 * out_bytes * (g - 1) / max(g, 1)
        elif op == "all-to-all":
            operand = out_bytes
            wire = out_bytes * (g - 1) / max(g, 1)
        else:  # collective-permute
            operand = out_bytes
            wire = out_bytes
        stats.counts[op] = stats.counts.get(op, 0) + 1
        stats.operand_bytes[op] = stats.operand_bytes.get(op, 0) + operand
        stats.wire_bytes[op] = stats.wire_bytes.get(op, 0) + wire
    return stats


@dataclass
class Roofline:
    """Per-device, per-step roofline terms (seconds)."""

    flops: float
    hbm_bytes: float
    collective_operand_bytes: float
    collective_wire_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float = 0.0
    useful_ratio: float = 0.0

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


def roofline_terms(
    per_device_flops: float,
    per_device_bytes: float,
    coll: CollectiveStats,
    model_flops_per_device: float = 0.0,
    links: int = 3,
) -> Roofline:
    compute_s = per_device_flops / PEAK_FLOPS_BF16
    memory_s = per_device_bytes / HBM_BW
    collective_s = coll.total_wire_bytes / (links * ICI_BW_PER_LINK)
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    useful = model_flops_per_device / per_device_flops if per_device_flops else 0.0
    return Roofline(
        flops=per_device_flops,
        hbm_bytes=per_device_bytes,
        collective_operand_bytes=float(coll.total_operand_bytes),
        collective_wire_bytes=float(coll.total_wire_bytes),
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops=model_flops_per_device,
        useful_ratio=useful,
    )
