"""Serving launcher CLI: loads (or random-inits) a model, runs the batched
engine over synthetic requests with int8 bit-sliced weights."""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced_config
from repro.models.runtime import RunFlags
from repro.models.transformer import init_params
from repro.serve.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--no-quant", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    flags = RunFlags(attn_chunk=64, flash_threshold=256, quant_serve=not args.no_quant)
    params = init_params(jax.random.key(0), cfg)
    engine = ServeEngine(cfg, params, flags, max_len=128)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(2, cfg.vocab_size, size=8).astype(np.int32),
                max_new_tokens=args.new_tokens)
        for i in range(args.requests)
    ]
    t0 = time.time()
    done = engine.run(reqs)
    dt = time.time() - t0
    total = sum(len(r.generated) for r in done)
    print(f"served {len(done)} requests, {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s, quant_serve={flags.quant_serve})")
    for r in done[:2]:
        print(f"  req {r.rid}: {r.generated[:8]}...")


if __name__ == "__main__":
    main()
