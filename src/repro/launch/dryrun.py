import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines, before any jax import: jax locks the device
# count at first init, and the production meshes need 512 placeholder devices.

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import (  # noqa: E402
    SHAPES,
    SHAPES_BY_NAME,
    cell_supported,
    get_config,
    list_archs,
)
from repro.dist.sharding import MeshRules  # noqa: E402
from repro.launch.hlo_analysis import parse_collectives, roofline_terms  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import input_specs  # noqa: E402
from repro.models.runtime import DEFAULT_FLAGS, RunFlags  # noqa: E402

RESULTS_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _model_flops_per_device(cfg, cell, n_devices: int) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE); decode D=batch tokens."""
    n = cfg.active_param_count()
    if cell.kind == "train":
        d = cell.tokens
        return 6.0 * n * d / n_devices
    if cell.kind == "prefill":
        d = cell.tokens
        return 2.0 * n * d / n_devices
    return 2.0 * n * cell.global_batch / n_devices  # decode: one token per seq


def _build_step_args(cfg, cell, rules, flags):
    specs = input_specs(cfg, cell, rules, flags)
    if cell.kind == "train":
        from repro.train.steps import make_train_step

        return make_train_step(cfg, flags, rules), (specs["state"], specs["batch"]), specs
    if cell.kind == "prefill":
        from repro.serve.engine import make_prefill_step

        return (
            make_prefill_step(cfg, flags, rules, max_len=cell.seq_len),
            (specs["params"], specs["batch"]),
            specs,
        )
    from repro.serve.engine import make_decode_step

    return (
        make_decode_step(cfg, flags, rules),
        (specs["params"], specs["cache"], specs["tokens"]),
        specs,
    )


def _lower_costs(cfg, cell, mesh, rules, flags):
    """(flops, hbm_bytes, CollectiveStats) for one lowering."""
    step, args, _ = _build_step_args(cfg, cell, rules, flags)
    with mesh:
        compiled = jax.jit(step).lower(*args).compile()
        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, (list, tuple)) else cost
        coll = parse_collectives(compiled.as_text())
    return float(cost.get("flops", 0.0)), float(cost.get("bytes accessed", 0.0)), coll


def _scan_corrected_costs(cfg, cell, mesh, rules, flags, measured):
    """XLA's cost_analysis counts a while-loop (scan) body ONCE regardless of
    trip count (verified empirically).  Recover the true per-step cost by
    lowering *unrolled* 1-group and 2-group variants:

        body   = u(2) - u(1);  outside = u(1) - body
        total  = outside + G · body

    applied to FLOPs, HBM bytes, and collective wire/operand bytes.
    """
    import dataclasses as dc

    g = cfg.pattern_groups()
    plen = len(cfg.block_pattern)
    u = []
    for k in (1, 2):
        small = dc.replace(
            cfg, n_layers=plen * k, n_enc_layers=(k if cfg.n_enc_layers else 0)
        )
        fl = dc.replace(flags, scan_layers=False)
        u.append(_lower_costs(small, cell, mesh, rules, fl))
    f1, b1, c1 = u[0]
    f2, b2, c2 = u[1]

    def corr(v1, v2, meas):
        body = max(v2 - v1, 0.0)
        outside = max(v1 - body, 0.0)
        return outside + g * body, body, outside

    flops, fbody, foutside = corr(f1, f2, measured[0])
    hbm, _, _ = corr(b1, b2, measured[1])
    wire, _, _ = corr(c1.total_wire_bytes, c2.total_wire_bytes, None)
    operand, _, _ = corr(float(c1.total_operand_bytes), float(c2.total_operand_bytes), None)
    return {
        "flops": flops,
        "hbm_bytes": hbm,
        "collective_wire_bytes": wire,
        "collective_operand_bytes": operand,
        "per_group_flops": fbody,
        "outside_flops": foutside,
        "groups": g,
    }


def lower_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    flags: RunFlags = DEFAULT_FLAGS,
    save: bool = True,
    verbose: bool = True,
    variant: str = "baseline",
    correction: bool = True,
) -> dict:
    cfg = get_config(arch)
    cell = SHAPES_BY_NAME[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    record: dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "variant": variant,
        "flags": dataclasses.asdict(flags),
    }
    ok, why = cell_supported(cfg, cell)
    if not ok:
        record.update(status="skipped", reason=why)
        return _finish(record, save, verbose)

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        rules = MeshRules.from_mesh(mesh)
        n_dev = mesh.size
        step, args, specs = _build_step_args(cfg, cell, rules, flags)

        with mesh:
            lowered = jax.jit(step).lower(*args)
            compiled = lowered.compile()
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            cost = cost[0] if isinstance(cost, (list, tuple)) else cost
            hlo = compiled.as_text()

        from repro.launch.memory_model import analytic_memory

        coll = parse_collectives(hlo)
        flops_raw = float(cost.get("flops", 0.0))
        hbm_raw = float(cost.get("bytes accessed", 0.0))
        if correction:
            corrected = _scan_corrected_costs(cfg, cell, mesh, rules, flags, (flops_raw, hbm_raw))
        else:  # multi-pod pass proves sharding/lowering; roofline is single-pod
            corrected = {
                "flops": flops_raw,
                "hbm_bytes": hbm_raw,
                "collective_wire_bytes": coll.total_wire_bytes,
                "collective_operand_bytes": float(coll.total_operand_bytes),
                "per_group_flops": 0.0,
                "outside_flops": 0.0,
                "groups": cfg.pattern_groups(),
                "corrected": False,
            }
        mf = _model_flops_per_device(cfg, cell, n_dev)
        from repro.launch.hlo_analysis import CollectiveStats

        coll_for_terms = CollectiveStats(
            counts=coll.counts,
            operand_bytes={"total": int(corrected["collective_operand_bytes"])},
            wire_bytes={"total": corrected["collective_wire_bytes"]},
        )
        rl = roofline_terms(corrected["flops"], corrected["hbm_bytes"], coll_for_terms, mf)
        record.update(
            status="ok",
            compile_s=round(time.time() - t0, 1),
            n_devices=n_dev,
            sharding_decisions=rules.decisions,
            memory={
                "argument_bytes_per_device": mem.argument_size_in_bytes,
                "output_bytes_per_device": mem.output_size_in_bytes,
                # NOTE: XLA-CPU buffer assignment does not reuse remat-region
                # buffers; this is a pessimistic bound (see memory_model.py).
                "temp_bytes_per_device_cpu_bound": mem.temp_size_in_bytes,
                "alias_bytes_per_device": mem.alias_size_in_bytes,
                "analytic": analytic_memory(cfg, cell, rules, flags, specs),
            },
            cost={
                "flops_raw_scanbody_once": flops_raw,
                "bytes_accessed_raw": hbm_raw,
                "scan_correction": corrected,
            },
            collectives=coll.to_json(),
            roofline=rl.to_json(),
        )
    except Exception as e:  # noqa: BLE001 — a failing cell is a bug report
        record.update(
            status="error",
            error=f"{type(e).__name__}: {e}",
            traceback=traceback.format_exc()[-4000:],
            compile_s=round(time.time() - t0, 1),
        )
    return _finish(record, save, verbose)


def _finish(record: dict, save: bool, verbose: bool) -> dict:
    if save:
        RESULTS_DIR.mkdir(parents=True, exist_ok=True)
        suffix = "" if record.get("variant", "baseline") == "baseline" else f"__{record['variant']}"
        name = f"{record['arch']}__{record['shape']}__{record['mesh']}{suffix}.json"
        (RESULTS_DIR / name).write_text(json.dumps(record, indent=2))
    if verbose:
        status = record["status"]
        line = f"[{record['mesh']}] {record['arch']:22s} {record['shape']:12s} {status}"
        if status == "ok":
            rl = record["roofline"]
            mem = record["memory"]
            line += (
                f"  compile={record['compile_s']}s"
                f"  mem={mem['analytic']['analytic_peak_per_device']/2**30:.2f}GiB/dev"
                f"(cpu-bound {mem['temp_bytes_per_device_cpu_bound']/2**30:.1f})"
                f"  dom={rl['dominant']}"
                f"  (c={rl['compute_s']:.2e}s m={rl['memory_s']:.2e}s n={rl['collective_s']:.2e}s)"
            )
        elif status == "error":
            line += f"  {record['error'][:160]}"
        else:
            line += f"  {record['reason'][:80]}"
        print(line, flush=True)
    return record


def main() -> None:
    ap = argparse.ArgumentParser(description="PIMSAB-framework multi-pod dry-run")
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape cell or 'all'")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--no-save", action="store_true")
    ap.add_argument("--no-correction", action="store_true",
                    help="skip the scan-cost correction lowerings (faster)")
    ap.add_argument("--skip-fresh", action="store_true",
                    help="skip cells whose saved record already has corrected costs")
    # RunFlags overrides (perf hillclimb levers)
    ap.add_argument("--attn-chunk", type=int, default=DEFAULT_FLAGS.attn_chunk)
    ap.add_argument("--flash-threshold", type=int, default=DEFAULT_FLAGS.flash_threshold)
    ap.add_argument("--no-triangular", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--no-quant-serve", action="store_true")
    ap.add_argument("--quant-kv", action="store_true")
    ap.add_argument("--seq-shard-kv", action="store_true")
    ap.add_argument("--no-scan-layers", action="store_true")
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--routing-groups", type=int, default=0)
    args = ap.parse_args()

    flags = RunFlags(
        attn_chunk=args.attn_chunk,
        flash_threshold=args.flash_threshold,
        triangular_attn=not args.no_triangular,
        remat=not args.no_remat,
        quant_serve=not args.no_quant_serve,
        quant_kv=args.quant_kv,
        seq_shard_kv=args.seq_shard_kv,
        scan_layers=not args.no_scan_layers,
        zero1=args.zero1,
        grad_accum=args.grad_accum,
        routing_groups=args.routing_groups,
    )
    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = [s.name for s in SHAPES] if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                if args.skip_fresh:
                    mesh_name = "pod2x16x16" if mp else "pod16x16"
                    f = RESULTS_DIR / f"{arch}__{shape}__{mesh_name}.json"
                    if f.exists():
                        rec = json.loads(f.read_text())
                        if rec.get("status") in ("ok", "skipped") and (
                            rec.get("status") == "skipped"
                            or "scan_correction" in rec.get("cost", {})
                        ):
                            continue
                rec = lower_cell(
                    arch, shape, mp, flags,
                    save=not args.no_save, variant=args.variant,
                    correction=not args.no_correction,
                )
                failures += rec["status"] == "error"
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")


if __name__ == "__main__":
    main()
