"""Runtime flags: knobs that change the *schedule*, not the architecture.

These are the levers the §Perf hillclimb turns: attention chunking/scheduling,
remat policy, quantized serving, MoE routing-group count.  They are orthogonal
to ModelConfig (which fixes the math) — the same arch can be lowered under
different RunFlags and compared in the roofline.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class RunFlags:
    # attention
    attn_chunk: int = 1024          # kv/q chunk for flash-style attention
    triangular_attn: bool = True    # causal chunk scheduling (skip j>i chunks)
    flash_threshold: int = 2048     # seqs longer than this use chunked attention
    # memory
    remat: bool = True              # checkpoint each block in train mode
    grad_accum: int = 1             # microbatches per step (activation memory / k)
    # PIMSAB bit-slice serving path
    quant_serve: bool = True        # serve with int8 bit-sliced weights
    quant_kv: bool = False          # int8 KV cache (adaptive precision on state)
    seq_shard_kv: bool = False      # shard KV-cache sequence dim over "model"
                                    # when kv-heads don't divide tp (ring-
                                    # attention-style distributed decode)
    # MoE
    routing_groups: int = 0         # 0 => one group per data shard
    # distribution
    zero1: bool = False             # shard optimizer state over the data axis
    grad_compress: bool = False     # int8 error-feedback gradient allreduce
    scan_layers: bool = True        # lax.scan over pattern groups

DEFAULT_FLAGS = RunFlags()
