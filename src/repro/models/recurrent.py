"""Recurrent temporal-mixing blocks: RG-LRU (RecurrentGemma/Griffin), and the
xLSTM pair (chunkwise-parallel mLSTM, sequential sLSTM).

All recurrences run in fp32 with log-space gate stabilization.  Each block has
two execution forms:

* sequence form (train/prefill): RG-LRU via ``jax.lax.associative_scan``;
  mLSTM via a chunkwise-parallel algorithm (intra-chunk quadratic + inter-chunk
  state recurrence) — both sub-quadratic in S and never materialize O(S^2);
  sLSTM is inherently sequential (recurrent weight matrices) and uses
  ``lax.scan`` over time.
* single-step form (decode): carries a fixed-size state — this is what makes
  the ``long_500k`` cell tractable for these families.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import Params, dense_init, linear, linear_init

# ---------------------------------------------------------------------------
# causal conv1d (width-K depthwise), used by RG-LRU and mLSTM blocks
# ---------------------------------------------------------------------------

CONV_K = 4


def causal_conv1d(u: jnp.ndarray, kernel: jnp.ndarray, state: Optional[jnp.ndarray] = None):
    """u: (B,S,W); kernel: (K,W) depthwise.  state: (B,K-1,W) trailing inputs
    of the previous segment.  Returns (y, new_state)."""
    b, s, w = u.shape
    k = kernel.shape[0]
    if state is None:
        state = jnp.zeros((b, k - 1, w), u.dtype)
    ext = jnp.concatenate([state, u], axis=1)  # (B, S+K-1, W)
    y = jnp.zeros_like(u)
    for j in range(k):
        y = y + ext[:, j : j + s] * kernel[j]
    return y, ext[:, -(k - 1) :]


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

_RGLRU_C = 8.0


def rglru_block_init(key, cfg, dtype) -> Params:
    d = cfg.d_model
    w = d  # lru_width == d_model in RecurrentGemma
    ks = jax.random.split(key, 7)
    return {
        "w_gate_branch": linear_init(ks[0], d, w, dtype),
        "w_rec_branch": linear_init(ks[1], d, w, dtype),
        "conv": {"kernel": (jax.random.normal(ks[2], (CONV_K, w)) * 0.1).astype(dtype)},
        "w_a": linear_init(ks[3], w, w, dtype),  # recurrence gate
        "w_i": linear_init(ks[4], w, w, dtype),  # input gate
        "lambda": jnp.full((w,), 2.0, jnp.float32),  # softplus(2)≈2.1 → slow decay
        "w_out": linear_init(ks[5], w, d, dtype),
    }


def _rglru_coeffs(p: Params, u: jnp.ndarray):
    """u: (..., W) conv output -> (log_a, x_in) in fp32."""
    r = jax.nn.sigmoid(linear(p["w_a"], u).astype(jnp.float32))
    i = jax.nn.sigmoid(linear(p["w_i"], u).astype(jnp.float32))
    log_a = -_RGLRU_C * jax.nn.softplus(p["lambda"]) * r
    beta = jnp.sqrt(-jnp.expm1(2.0 * log_a))  # sqrt(1 - a^2), stable
    x_in = beta * (i * u.astype(jnp.float32))
    return log_a, x_in


def rglru_block_apply(p: Params, x: jnp.ndarray, cfg, state: Optional[Dict] = None):
    """x: (B,S,d).  Returns (y, new_state) with state {"h": (B,W), "conv": (B,K-1,W)}."""
    gate = jax.nn.gelu(linear(p["w_gate_branch"], x).astype(jnp.float32)).astype(x.dtype)
    u0 = linear(p["w_rec_branch"], x)
    conv_state = state["conv"] if state else None
    u, conv_state = causal_conv1d(u0, p["conv"]["kernel"], conv_state)
    log_a, x_in = _rglru_coeffs(p, u)
    if x.shape[1] == 1 and state is not None:  # decode step
        h = state["h"] * jnp.exp(log_a[:, 0]) + x_in[:, 0]
        hs = h[:, None]
    else:
        a = jnp.exp(log_a)
        if state is not None:  # chain from carried state
            x_in = x_in.at[:, 0].add(a[:, 0] * state["h"])

        def comb(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2

        _, hs = jax.lax.associative_scan(comb, (a, x_in), axis=1)
        h = hs[:, -1]
    y = linear(p["w_out"], (gate.astype(jnp.float32) * hs).astype(x.dtype))
    return y, {"h": h, "conv": conv_state}


def rglru_state_init(cfg, batch: int, dtype=jnp.float32) -> Dict:
    w = cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, CONV_K - 1, w), jnp.dtype(cfg.dtype)),
    }


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix memory) — chunkwise parallel
# ---------------------------------------------------------------------------


def _mlstm_dims(cfg) -> Tuple[int, int, int]:
    pf = 2 * cfg.d_model  # projection factor 2
    h = cfg.n_heads
    return pf, h, pf // h


def mlstm_block_init(key, cfg, dtype) -> Params:
    d = cfg.d_model
    pf, h, dh = _mlstm_dims(cfg)
    ks = jax.random.split(key, 9)
    return {
        "w_up": linear_init(ks[0], d, 2 * pf, dtype),  # [x_m | z-gate]
        "conv": {"kernel": (jax.random.normal(ks[1], (CONV_K, pf)) * 0.1).astype(dtype)},
        "w_q": linear_init(ks[2], pf, pf, dtype),
        "w_k": linear_init(ks[3], pf, pf, dtype),
        "w_v": linear_init(ks[4], pf, pf, dtype),
        "w_if": linear_init(ks[5], pf, 2 * h, dtype),  # per-head scalar gates
        "gn_scale": jnp.ones((pf,), dtype),
        "w_down": linear_init(ks[6], pf, d, dtype),
    }


def _heads(x, h):  # (B,S,pf) -> (B,S,H,dh)
    b, s, _ = x.shape
    return x.reshape(b, s, h, -1)


def _mlstm_chunk_scan(q, k, v, ig, lf, state, chunk: int):
    """Chunkwise stabilized mLSTM.

    q,k,v: (B,S,H,dh) — q pre-scaled by 1/sqrt(dh).
    ig, lf: (B,S,H) log input gate (ĩ) and log forget gate (logsigmoid f̃).
    state: dict C (B,H,dh,dh), n (B,H,dh), m (B,H).
    Returns (y (B,S,H,dh), new_state).
    """
    b, s, h, dh = q.shape
    l = min(chunk, s)
    pad = (-s) % l
    if pad:
        q, k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0))) for t in (q, k, v))
        ig = jnp.pad(ig, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
        lf = jnp.pad(lf, ((0, 0), (0, pad), (0, 0)))
    sp = s + pad
    nc = sp // l
    # (nc, B, H, L, ...) layout for scan
    qc = q.reshape(b, nc, l, h, dh).transpose(1, 0, 3, 2, 4)
    kc = k.reshape(b, nc, l, h, dh).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, nc, l, h, dh).transpose(1, 0, 3, 2, 4)
    igc = ig.reshape(b, nc, l, h).transpose(1, 0, 3, 2)  # (nc,B,H,L)
    lfc = lf.reshape(b, nc, l, h).transpose(1, 0, 3, 2)
    tri = jnp.tril(jnp.ones((l, l), bool))

    def step(carry, xs):
        C, n, m = carry  # (B,H,dh,dh), (B,H,dh), (B,H)
        qi, ki, vi, ii, fi = xs
        bcum = jnp.cumsum(fi, axis=-1)  # (B,H,L) inclusive log-decay F_t
        g = ii - bcum  # g_s = ĩ_s - F_s
        gmax = jax.lax.cummax(g, axis=g.ndim - 1)
        m_t = jnp.maximum(m[..., None] + bcum, bcum + gmax)  # (B,H,L)
        # inter-chunk: queries read incoming state
        dec_in = jnp.exp(m[..., None] + bcum - m_t)  # (B,H,L)
        y_inter = jnp.einsum("bhld,bhde->bhle", qi, C) * dec_in[..., None]
        n_inter = jnp.einsum("bhld,bhd->bhl", qi, n) * dec_in
        # intra-chunk: D_ts = exp(F_t - F_s + ĩ_s - m_t), s<=t
        logd = bcum[..., :, None] - bcum[..., None, :] + ii[..., None, :] - m_t[..., None]
        logd = jnp.where(tri, logd, -1e30)
        d_mat = jnp.exp(logd)  # (B,H,L,L)
        s_mat = jnp.einsum("bhld,bhsd->bhls", qi, ki) * d_mat
        y_intra = jnp.einsum("bhls,bhsd->bhld", s_mat, vi)
        n_intra = jnp.sum(s_mat, axis=-1)
        denom = jnp.maximum(jnp.abs(n_inter + n_intra), jnp.exp(-m_t))[..., None]
        y = (y_inter + y_intra) / denom  # (B,H,L,dh)
        # state update to end of chunk
        btot = bcum[..., -1]  # (B,H)
        m_new = jnp.maximum(m + btot, btot + gmax[..., -1])
        w_state = jnp.exp(m + btot - m_new)  # old-state decay
        w_in = jnp.exp(btot[..., None] - bcum + ii - m_new[..., None])  # (B,H,L)
        C_new = C * w_state[..., None, None] + jnp.einsum(
            "bhl,bhld,bhle->bhde", w_in, ki, vi
        )
        n_new = n * w_state[..., None] + jnp.einsum("bhl,bhld->bhd", w_in, ki)
        return (C_new, n_new, m_new), y

    (C, n, m), ys = jax.lax.scan(step, (state["C"], state["n"], state["m"]), (qc, kc, vc, igc, lfc))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(b, sp, h, dh)[:, :s]
    return y, {"C": C, "n": n, "m": m}


def _mlstm_decode_step(q, k, v, ig, lf, state):
    """Single step.  q,k,v: (B,H,dh); ig,lf: (B,H)."""
    C, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(lf + m, ig)
    f_w = jnp.exp(lf + m - m_new)
    i_w = jnp.exp(ig - m_new)
    C = C * f_w[..., None, None] + i_w[..., None, None] * (k[..., :, None] * v[..., None, :])
    n = n * f_w[..., None] + i_w[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, C)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n)), jnp.exp(-m_new))
    y = num / denom[..., None]
    return y, {"C": C, "n": n, "m": m_new}


def _groupnorm_heads(x, scale, eps=1e-5):
    """Per-head layernorm (no mean-center: RMS) over dh.  x: (B,S,H,dh)."""
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps)


def mlstm_block_apply(p: Params, x: jnp.ndarray, cfg, state: Optional[Dict] = None, chunk: int = 256):
    b, s, d = x.shape
    pf, h, dh = _mlstm_dims(cfg)
    up = linear(p["w_up"], x)
    xm, z = jnp.split(up, 2, axis=-1)
    conv_state = state["conv"] if state else None
    xc, conv_state = causal_conv1d(xm, p["conv"]["kernel"], conv_state)
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)
    q = _heads(linear(p["w_q"], xc), h).astype(jnp.float32) / math.sqrt(dh)
    k = _heads(linear(p["w_k"], xc), h).astype(jnp.float32)
    v = _heads(linear(p["w_v"], xm), h).astype(jnp.float32)
    gates = linear(p["w_if"], xc).astype(jnp.float32)  # (B,S,2H)
    ig, fg = jnp.split(gates, 2, axis=-1)
    lf = jax.nn.log_sigmoid(fg)
    if state is None:
        cell = mlstm_state_init(cfg, b)
    else:
        cell = {k2: state[k2] for k2 in ("C", "n", "m")}
    if s == 1 and state is not None:  # decode
        y, cell = _mlstm_decode_step(q[:, 0], k[:, 0], v[:, 0], ig[:, 0], lf[:, 0], cell)
        y = y[:, None]
    else:
        y, cell = _mlstm_chunk_scan(q, k, v, ig, lf, cell, chunk)
    y = _groupnorm_heads(y, None).reshape(b, s, pf).astype(x.dtype) * p["gn_scale"]
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = linear(p["w_down"], y)
    return out, {"C": cell["C"], "n": cell["n"], "m": cell["m"], "conv": conv_state}


def mlstm_state_init(cfg, batch: int) -> Dict:
    pf, h, dh = _mlstm_dims(cfg)
    return {
        "C": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, h, dh), jnp.float32),
        "m": jnp.zeros((batch, h), jnp.float32),
    }


def mlstm_full_state_init(cfg, batch: int) -> Dict:
    st = mlstm_state_init(cfg, batch)
    pf, _, _ = _mlstm_dims(cfg)
    st["conv"] = jnp.zeros((batch, CONV_K - 1, pf), jnp.dtype(cfg.dtype))
    return st


# ---------------------------------------------------------------------------
# sLSTM (scalar memory, true recurrence) — sequential scan
# ---------------------------------------------------------------------------


def slstm_block_init(key, cfg, dtype) -> Params:
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 7)
    ffd = ((4 * d // 3) + 63) // 64 * 64
    return {
        "w_in": linear_init(ks[0], d, 4 * d, dtype),  # z,i,f,o input projections
        "r": (jax.random.normal(ks[1], (4, h, dh, dh)) * (1.0 / math.sqrt(dh))).astype(dtype),
        "gn_scale": jnp.ones((d,), dtype),
        "w_up": linear_init(ks[2], d, 2 * ffd, dtype),  # GeGLU post-up FFN
        "w_down": linear_init(ks[3], ffd, d, dtype),
    }


def _slstm_cell(p, xz, xi, xf, xo, state, h_heads):
    """One timestep.  x*: (B,H,dh) pre-activations from the input projection."""
    c, n, hprev, m = state  # each (B,H,dh)
    rz, ri, rf, ro = (p["r"][j] for j in range(4))
    z = jnp.tanh(xz + jnp.einsum("bhd,hde->bhe", hprev, rz))
    it = xi + jnp.einsum("bhd,hde->bhe", hprev, ri)
    ft = xf + jnp.einsum("bhd,hde->bhe", hprev, rf)
    ot = jax.nn.sigmoid(xo + jnp.einsum("bhd,hde->bhe", hprev, ro))
    lf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(lf + m, it)
    i_w = jnp.exp(it - m_new)
    f_w = jnp.exp(lf + m - m_new)
    c_new = f_w * c + i_w * z
    n_new = jnp.maximum(f_w * n + i_w, jnp.exp(-m_new))
    h_new = ot * c_new / n_new
    return (c_new, n_new, h_new, m_new), h_new


def slstm_block_apply(p: Params, x: jnp.ndarray, cfg, state: Optional[Dict] = None):
    b, s, d = x.shape
    h = cfg.n_heads
    dh = d // h
    pre = linear(p["w_in"], x).astype(jnp.float32)  # (B,S,4d)
    pre = pre.reshape(b, s, 4, h, dh)
    if state is None:
        st = slstm_state_init(cfg, b)
    else:
        st = state
    cell = (st["c"], st["n"], st["h"], st["m"])

    def step(carry, xs):
        return _slstm_cell(p, xs[:, 0], xs[:, 1], xs[:, 2], xs[:, 3], carry, h)

    cell, hs = jax.lax.scan(step, cell, pre.transpose(1, 0, 2, 3, 4))  # scan over S
    hs = hs.transpose(1, 0, 2, 3)  # (B,S,H,dh)
    hs = _groupnorm_heads(hs, None).reshape(b, s, d).astype(x.dtype) * p["gn_scale"]
    # post-up GeGLU FFN
    up = linear(p["w_up"], hs)
    g, u = jnp.split(up, 2, axis=-1)
    y = linear(p["w_down"], jax.nn.gelu(g.astype(jnp.float32)).astype(x.dtype) * u)
    c, n, hh, m = cell
    return y, {"c": c, "n": n, "h": hh, "m": m}


def slstm_state_init(cfg, batch: int) -> Dict:
    h, dh = cfg.n_heads, cfg.d_model // cfg.n_heads
    z = jnp.zeros((batch, h, dh), jnp.float32)
    return {"c": z, "n": z + 1.0, "h": z, "m": z}
