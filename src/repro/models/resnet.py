"""ResNet18-style integer CNN built entirely from registry kernels.

This is the end-to-end DL-network workload of the paper's headline
evaluation, expressed so the *whole forward pass* can be captured by
``api.trace`` and compiled onto the pimsab backend as ONE fused
``WorkloadGraph``: every op is a registry kernel (``conv2d`` / ``relu`` /
``maxpool2d`` / ``avgpool2d`` / ``ewise_add`` / ``global_avgpool`` /
``int_matmul``), and the residual connections make the captured Program a
genuine DAG — multi-consumer values (the block input feeds both the conv
path and the shortcut) and fan-in nodes (the residual add) with reconvergent
paths.

The network runs in the **raw integer domain** end to end: int8-range inputs
and weights, int32 accumulation (wrapping, like the oracle), integer pooling
with floor-divide semantics.  That is what makes pimsab execution bit-exact
against the JAX oracle — and what lets integer producer→consumer boundaries
(conv accumulator → relu / residual add) stay CRAM-resident in program mode.

Per-layer precision: program-mode lowering cannot calibrate operand
precision from values, so :func:`forward` threads a *static worst-case bit
bound* through the network (``bits_out = bits_in + bits_w + ceil(log2 K)``
per conv, ``+1`` per residual add, capped at 32 where wraparound matches
int32 exactly) and passes it to each kernel as ``x_bits`` — the §IV-C
adaptive-precision idea applied network-wide at trace time.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.kernels import api

Params = Dict[str, Any]


@dataclass(frozen=True)
class ResNetConfig:
    """A parameterizable BasicBlock ResNet (ResNet18 shape at the defaults'
    full scale; the tiny presets keep bit-serial functional simulation
    tractable).

    ``stage_channels[i]`` / ``blocks_per_stage[i]`` describe stage i; every
    stage after the first downsamples spatially by 2 (stride-2 first conv +
    1×1 projection shortcut).  ``input_hw`` must leave the final feature map
    with a power-of-two spatial count (the pimsab global-avgpool divide is a
    shift-read).
    """

    in_channels: int = 3
    input_hw: int = 32
    stem_channels: int = 8
    stem_pool: Optional[str] = "max"  # "max" | "avg" | None (2×2, stride 2)
    stage_channels: Tuple[int, ...] = (8, 16)
    blocks_per_stage: Tuple[int, ...] = (2, 2)
    num_classes: int = 10
    input_bits: int = 4   # operand magnitude bound of the quantized input
    weight_bits: int = 3  # weights drawn from the signed weight_bits range

    def __post_init__(self):
        assert len(self.stage_channels) == len(self.blocks_per_stage)

    @property
    def final_hw(self) -> int:
        hw = self.input_hw
        if self.stem_pool:
            hw //= 2
        return hw // (2 ** (len(self.stage_channels) - 1))


# A functional-simulation-sized instance: one 8×8 image through a stem,
# a stem pool, two stages (one BasicBlock each, the second downsampling),
# global pool over 2×2 and a 10-class head — every layer kind the full
# network has, small enough for bit-serial execution in seconds.
TINY = ResNetConfig(
    in_channels=3, input_hw=8, stem_channels=8, stem_pool="max",
    stage_channels=(8, 16), blocks_per_stage=(1, 1), num_classes=10,
)

# The paper-shaped evaluation config (ResNet18 topology at CIFAR scale):
# 4 stages × 2 BasicBlocks.  Used timing-only (full-chip analytic model).
RESNET18 = ResNetConfig(
    in_channels=3, input_hw=32, stem_channels=64, stem_pool=None,
    stage_channels=(64, 128, 256, 512), blocks_per_stage=(2, 2, 2, 2),
    num_classes=1000,
)


def _winit(rng: np.random.Generator, shape: Tuple[int, ...], bits: int) -> jnp.ndarray:
    """Weights uniform over the signed ``bits`` range (int32 storage)."""
    lim = 2 ** (bits - 1)
    return jnp.asarray(rng.integers(-lim + 1, lim, shape), jnp.int32)


def init_params(cfg: ResNetConfig, seed: int = 0) -> Params:
    """Deterministic integer parameters for ``cfg`` (int32 arrays holding
    ``weight_bits``-range values)."""
    rng = np.random.default_rng(seed)
    wb = cfg.weight_bits
    params: Params = {
        "stem": _winit(rng, (cfg.stem_channels, cfg.in_channels, 3, 3), wb),
        "stages": [],
    }
    c_in = cfg.stem_channels
    for si, (c_out, n_blocks) in enumerate(
        zip(cfg.stage_channels, cfg.blocks_per_stage)
    ):
        blocks: List[Params] = []
        for bi in range(n_blocks):
            stride = 2 if (si > 0 and bi == 0) else 1
            block: Params = {
                "conv1": _winit(rng, (c_out, c_in, 3, 3), wb),
                "conv2": _winit(rng, (c_out, c_out, 3, 3), wb),
            }
            if stride != 1 or c_in != c_out:
                block["proj"] = _winit(rng, (c_out, c_in, 1, 1), wb)
            blocks.append(block)
            c_in = c_out
        params["stages"].append(blocks)
    params["head"] = _winit(rng, (c_in, cfg.num_classes), wb)
    return params


def make_input(cfg: ResNetConfig, batch: int = 1, seed: int = 1) -> jnp.ndarray:
    """A quantized input image batch within the config's ``input_bits`` range."""
    rng = np.random.default_rng(seed)
    lim = 2 ** (cfg.input_bits - 1)
    return jnp.asarray(
        rng.integers(-lim + 1, lim, (batch, cfg.in_channels, cfg.input_hw, cfg.input_hw)),
        jnp.int32,
    )


def _conv_out_bits(bits_in: int, bits_w: int, k: int) -> int:
    """Static worst-case precision of a K-term integer conv/matmul output,
    capped at 32 (where the CRAM accumulator's wraparound == int32)."""
    return min(bits_in + bits_w + math.ceil(math.log2(max(k, 2))), 32)


def forward(cfg: ResNetConfig, params: Params, x: jnp.ndarray) -> jnp.ndarray:
    """The traced forward pass: ``(B, C, H, W) int32 → (B, num_classes) int32``.

    Pure registry-kernel composition (traceable by ``api.trace``); the
    residual blocks make the captured Program a branch-and-merge DAG.
    """
    wb = cfg.weight_bits
    bits = cfg.input_bits

    h = api.conv2d(x, params["stem"], stride=1, padding=1, x_bits=bits, w_bits=wb)
    bits = _conv_out_bits(bits, wb, cfg.in_channels * 9)
    h = api.relu(h)
    if cfg.stem_pool == "max":
        h = api.maxpool2d(h, window=2)
    elif cfg.stem_pool == "avg":
        h = api.avgpool2d(h, window=2)
        # the 2×2 pool sums four values (+2 bits, capped at 32) and then
        # shift-divides them back out — the stored bound is unchanged until
        # the cap bites (same formula as the global pool below)
        bits = max(2, min(bits + 2, 32) - 2)

    c_in = cfg.stem_channels
    for si, blocks in enumerate(params["stages"]):
        c_out = cfg.stage_channels[si]
        for bi, block in enumerate(blocks):
            stride = 2 if (si > 0 and bi == 0) else 1
            identity, id_bits = h, bits
            y = api.conv2d(h, block["conv1"], stride=stride, padding=1,
                           x_bits=bits, w_bits=wb)
            b1 = _conv_out_bits(bits, wb, c_in * 9)
            y = api.relu(y)
            y = api.conv2d(y, block["conv2"], stride=1, padding=1,
                           x_bits=b1, w_bits=wb)
            b2 = _conv_out_bits(b1, wb, c_out * 9)
            if "proj" in block:
                identity = api.conv2d(h, block["proj"], stride=stride, padding=0,
                                      x_bits=bits, w_bits=wb)
                id_bits = _conv_out_bits(bits, wb, c_in)
            h = api.relu(api.ewise_add(y, identity))
            bits = min(max(b2, id_bits) + 1, 32)
            c_in = c_out

    h = api.global_avgpool(h)
    # the pool sums gap_k values (+log2 bits, capped) then shift-divides
    # them back out; the head sees the stored (post-shift) precision
    gap_k = cfg.final_hw * cfg.final_hw
    shift = int(math.log2(max(gap_k, 1)))
    bits = max(2, min(bits + shift, 32) - shift)
    return api.int_matmul(h, params["head"], x_bits=bits, w_bits=wb)


def layer_names(cfg: ResNetConfig) -> List[str]:
    """The kernel sequence :func:`forward` emits, in trace order — the labels
    a per-layer SimReport breakdown lines up with."""
    names = ["conv2d", "relu"]
    if cfg.stem_pool == "max":
        names.append("maxpool2d")
    elif cfg.stem_pool == "avg":
        names.append("avgpool2d")
    c_in = cfg.stem_channels
    for si, n_blocks in enumerate(cfg.blocks_per_stage):
        c_out = cfg.stage_channels[si]
        for bi in range(n_blocks):
            stride = 2 if (si > 0 and bi == 0) else 1
            names += ["conv2d", "relu", "conv2d"]
            if stride != 1 or c_in != c_out:
                names.append("conv2d")  # projection shortcut
            names += ["ewise_add", "relu"]
            c_in = c_out
    names += ["global_avgpool", "int_matmul"]
    return names
