"""Mixture-of-Experts FFN with sort-based capacity dispatch.

Routing runs independently per *routing group* (one group per data shard by
default) so the dispatch buffers stay sharded over the data axis while the
expert axis shards over "model" — the same rule PIMSAB's compiler applies:
data-parallel loops map across tiles (data axis), reductions stay local.

Dispatch is gather/scatter-based (no (T, E, C) one-hot einsum): tokens are
argsorted by expert id, their position within the expert segment is computed
with a searchsorted, over-capacity tokens are dropped, and the kept tokens are
scattered into an (E, C, D) buffer that feeds a batched expert matmul.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import Params, dense_init, swiglu


def moe_init(key, cfg, dtype) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": {"w": dense_init(ks[0], d, e, jnp.float32)},
        "w_gate": dense_init(ks[1], e * d, f, dtype).reshape(e, d, f),
        "w_up": dense_init(ks[2], e * d, f, dtype).reshape(e, d, f),
        "w_down": dense_init(ks[3], e * f, d, dtype).reshape(e, f, d),
    }


def _route_group(x: jnp.ndarray, logits: jnp.ndarray, k: int, capacity: int):
    """Single routing group.  x: (T, D); logits: (T, E) fp32.

    Returns (buf (E*C, D), combine info) for gather-based un-dispatch.
    """
    t, e = logits.shape
    gates, eidx = jax.lax.top_k(logits, k)  # (T,k)
    gates = jax.nn.softmax(gates, axis=-1)
    flat_e = eidx.reshape(-1)  # (T*k,)
    flat_t = jnp.repeat(jnp.arange(t), k)
    flat_g = gates.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    # position of each routed token within its expert's segment
    seg_start = jnp.searchsorted(se, jnp.arange(e), side="left")  # (E,)
    pos = jnp.arange(t * k) - seg_start[se]
    keep = pos < capacity
    slot = jnp.where(keep, se * capacity + pos, e * capacity)  # overflow row
    buf = jnp.zeros((e * capacity + 1, x.shape[-1]), x.dtype).at[slot].set(x[st])
    return buf[: e * capacity], (slot, st, sg, keep)


def _combine_group(y: jnp.ndarray, info, t: int) -> jnp.ndarray:
    """y: (E*C, D_out) expert outputs -> (T, D_out)."""
    slot, st, sg, keep = info
    contrib = y[jnp.where(keep, slot, 0)]
    contrib = contrib * jnp.where(keep, sg, 0.0).astype(contrib.dtype)[:, None]
    return jnp.zeros((t, y.shape[-1]), y.dtype).at[st].add(contrib)


def moe_ffn(p: Params, x: jnp.ndarray, cfg, n_groups: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out, aux_loss).  Routed per group of B*S/n_groups tokens."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    tokens = b * s
    assert tokens % n_groups == 0, (tokens, n_groups)
    tg = tokens // n_groups
    capacity = max(k, int(math.ceil(tg * k / e * cfg.moe_capacity_factor)))
    xg = x.reshape(n_groups, tg, d)
    logits = (xg.astype(jnp.float32) @ p["router"]["w"])  # (G, Tg, E)

    def per_group(xi, li):
        buf, info = _route_group(xi, li, k, capacity)  # (E*C, D)
        buf = buf.reshape(e, capacity, d)
        gate = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
        up = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
        act = swiglu(gate, up)
        down = jnp.einsum("ecf,efd->ecd", act, p["w_down"])
        return _combine_group(down.reshape(e * capacity, d), info, tg)

    out = jax.vmap(per_group)(xg, logits)
    # Switch-style load-balance aux loss
    probs = jax.nn.softmax(logits, axis=-1)  # (G, Tg, E)
    me = jnp.mean(probs, axis=1)  # (G, E) router prob mass
    top1 = jnp.argmax(logits, axis=-1)
    ce = jnp.mean(jax.nn.one_hot(top1, e, dtype=jnp.float32), axis=1)  # (G, E) dispatch mass
    aux = e * jnp.mean(jnp.sum(me * ce, axis=-1))
    return out.reshape(b, s, d), aux
