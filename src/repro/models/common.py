"""Shared model building blocks: norms, RoPE, and the (optionally bit-plane
quantized) linear layer.

The quantized path is the TPU-native form of PIMSAB's bit-serial-aware
computation: integer tensors are decomposed into ``slice_bits``-wide slices
(radix-2**slice_bits bit-slicing — the MXU int8 path plays the role of the
paper's 1-bit PE array), plane-pair matmuls run with int32 accumulation, and
results are recombined with shifts.  Adaptive precision = fewer slices;
``mul_const`` zero-bit skipping = statically dropping all-zero weight slices.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import api
from repro.kernels.api import PrecisionSpec, SlicedTensor

Params = Dict[str, Any]


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * p["scale"]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,hd/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Quantized (bit-sliced) linear — PIMSAB adaptive precision on the MXU
# ---------------------------------------------------------------------------


def quantize_weight(w: jnp.ndarray, bits: int = 8) -> Params:
    """Symmetric per-output-channel int quantization of a (..., d_in, d_out)
    weight (leading axes: scan-group stacking)."""
    wf = w.astype(jnp.float32)
    qmax = 2 ** (bits - 1) - 1
    scale = jnp.max(jnp.abs(wf), axis=-2, keepdims=True) / qmax  # (..., 1, d_out)
    scale = jnp.maximum(scale, 1e-8)
    w_q = jnp.clip(jnp.round(wf / scale), -qmax - 1, qmax).astype(jnp.int8)
    return {"w_q": w_q, "w_scale": scale.astype(jnp.float32)}


def _dynamic_act_quant(x: jnp.ndarray, bits: int):
    qmax = 2 ** (bits - 1) - 1
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / qmax
    scale = jnp.maximum(scale, 1e-8)
    x_q = jnp.clip(jnp.round(xf / scale), -qmax - 1, qmax).astype(jnp.int8)
    return x_q, scale


def int_matmul(x_q: jnp.ndarray, w_q: jnp.ndarray) -> jnp.ndarray:
    """int8 × int8 → int32 matmul (one bit-slice plane-pair pass on the MXU)."""
    return jax.lax.dot_general(
        x_q,
        w_q,
        (((x_q.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def quant_linear(
    p: Params, x: jnp.ndarray, spec: PrecisionSpec = PrecisionSpec.int8
) -> jnp.ndarray:
    """Bit-sliced integer linear: dynamic act quant + int32 accumulation.

    When the spec fits one slice pair (act/weight bits ≤ slice_bits, the
    int8 serving default) this is a single MXU pass; wider specs go through
    :func:`repro.kernels.api.matmul` over ``SlicedTensor`` operands, which
    splits into slices, skips statically-zero ones, and recombines with
    shifts.
    """
    if spec.single_pass:
        x_q, x_scale = _dynamic_act_quant(x, spec.act_bits)
        acc = int_matmul(x_q, p["w_q"])
        out = acc.astype(jnp.float32) * x_scale * p["w_scale"]
    else:
        lead = x.shape[:-1]
        x_st = SlicedTensor.quantize(x.reshape(-1, x.shape[-1]), spec)
        w_st = SlicedTensor.from_int(
            p["w_q"].astype(jnp.int32), spec.weight_bits,
            slice_bits=spec.slice_bits, scale=p["w_scale"].reshape(-1),
        )
        out = api.matmul(x_st, w_st).reshape(*lead, -1)
    if "b" in p:
        out = out + p["b"].astype(jnp.float32)
    return out.astype(x.dtype)


def linear(
    p: Params, x: jnp.ndarray, spec: Optional[PrecisionSpec] = None
) -> jnp.ndarray:
    """Dispatch: quantized (bit-slice) if the param leaf is quantized."""
    if "w_q" in p:
        return quant_linear(p, x, spec or PrecisionSpec.int8)
    out = x @ p["w"]
    if "b" in p:
        out = out + p["b"]
    return out


def linear_init(key, d_in: int, d_out: int, dtype, bias: bool = False) -> Params:
    p: Params = {"w": dense_init(key, d_in, d_out, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


# ---------------------------------------------------------------------------
# Program-built blocks (trace → compile-once → execute)
# ---------------------------------------------------------------------------


def _matmul_relu_chain(x_st: SlicedTensor, w_st: SlicedTensor) -> jnp.ndarray:
    # scale-less operands: the integer accumulator feeds relu directly, so on
    # the pimsab backend the intermediate stays CRAM-resident (DRAM elided)
    return api.relu(api.matmul(x_st, w_st))


_matmul_relu = api.trace(_matmul_relu_chain, name="quant_linear_relu")


def quant_linear_relu(
    p: Params, x: jnp.ndarray, spec: Optional[PrecisionSpec] = None
) -> jnp.ndarray:
    """``relu(x @ W)`` over a quantized weight, built as one traced Program.

    The matmul→relu chain compiles once per (shape, PrecisionSpec, backend)
    signature and replays through the cached Executor; on the pimsab backend
    the linear's accumulator never round-trips through DRAM before the relu.
    Scales factor out of relu (they are positive by construction), so the
    program runs in the raw integer domain and dequantizes afterwards.
    Falls back to the eager composition for tracers (under ``jax.jit``),
    unquantized params, or a bias (relu doesn't commute with ``+ b``).
    """
    spec = spec or PrecisionSpec.int8
    if "w_q" not in p or "b" in p or api.static_value(x) is None:
        return jnp.maximum(linear(p, x, spec), 0)
    lead = x.shape[:-1]
    x_st = SlicedTensor.quantize(x.reshape(-1, x.shape[-1]), spec)
    x_raw = SlicedTensor(  # scale-less view: keep zero-slice skip metadata
        slices=x_st.slices, slice_bits=x_st.slice_bits,
        orig_bits=x_st.orig_bits, zero_slices=x_st.zero_slices,
    )
    w_st = SlicedTensor.from_int(
        p["w_q"].astype(jnp.int32), spec.weight_bits, slice_bits=spec.slice_bits
    )
    raw = _matmul_relu(x_raw, w_st)
    out = raw.astype(jnp.float32) * x_st.scale.reshape(-1, 1) * p["w_scale"].reshape(1, -1)
    return out.reshape(*lead, -1).astype(x.dtype)


def maybe_quantize_tree(params, cfg, path: str = "") -> Any:
    """Transform a param tree for serving: every linear {'w': ...} leaf-dict
    becomes {'w_q': int8, 'w_scale': f32} (PIMSAB: weights live bit-sliced).

    Embedding and normalization weights stay high-precision (they are
    gathered, not matmul'd).
    """
    if not cfg.quant.enabled:
        return params
    spec = PrecisionSpec.from_quant_config(cfg.quant)
    skip = ("embed", "norm", "scale", "lambda", "conv", "gate_bias", "router")

    def rec(node, path):
        if isinstance(node, dict):
            # ndim 2 = plain linear; ndim 3 = scan-stacked (G, d_in, d_out) —
            # per-group quantization; lax.scan slices both w_q and w_scale
            if "w" in node and node["w"].ndim in (2, 3) and not any(s in path for s in skip):
                q = quantize_weight(node["w"], spec.weight_bits)
                if "b" in node:
                    q["b"] = node["b"]
                return q
            return {k: rec(v, f"{path}/{k}") for k, v in node.items()}
        return node

    return rec(params, path)


# ---------------------------------------------------------------------------
# activations / losses
# ---------------------------------------------------------------------------


def swiglu(gate: jnp.ndarray, up: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.silu(gate.astype(jnp.float32)).astype(gate.dtype) * up


def softmax_cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray, vocab: int) -> jnp.ndarray:
    """Mean token cross-entropy; logits over padded vocab are masked."""
    lf = logits.astype(jnp.float32)
    if lf.shape[-1] > vocab:
        pad = lf.shape[-1] - vocab
        lf = lf - jnp.pad(jnp.zeros((vocab,)), (0, pad), constant_values=1e9)
    logz = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
