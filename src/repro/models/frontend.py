"""Modality frontend STUBS (as directed by the assignment).

The audio (whisper conv-mel) and vision (CLIP) frontends are not reproduced;
``input_specs()`` supplies precomputed frame/patch embeddings.  Only the thin
adapter projections that fuse those embeddings into the backbone live here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import Params, linear, linear_init


def vision_adapter_init(key, cfg, dtype) -> Params:
    d = cfg.d_model
    return {"proj": linear_init(key, d, d, dtype)}


def fuse_patches(p: Params, x: jnp.ndarray, patch_embeds: jnp.ndarray) -> jnp.ndarray:
    """Add projected patch embeddings into the first n_patches positions."""
    n = min(patch_embeds.shape[1], x.shape[1])
    proj = linear(p["proj"], patch_embeds[:, :n].astype(x.dtype))
    return x.at[:, :n].add(proj)


def audio_adapter_init(key, cfg, dtype) -> Params:
    d = cfg.d_model
    return {"proj": linear_init(key, d, d, dtype)}


def embed_frames(p: Params, frame_embeds: jnp.ndarray) -> jnp.ndarray:
    """Project precomputed (B, T_frames, D) mel-frame embeddings."""
    return linear(p["proj"], frame_embeds)
