"""Attention: GQA full/causal, flash-style chunked (online softmax), windowed
local, cross, and single-token decode.

Long sequences never materialize O(S^2) score tensors: ``chunked_attention``
scans KV chunks carrying (max, denom, acc) — the standard online-softmax
recurrence.  With ``triangular=True`` the causal schedule only visits chunks
j ≤ i (halves attention FLOPs vs. the masked-full baseline; this is one of the
§Perf hillclimb levers).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.api import PrecisionSpec

NEG_INF = -1e30


def _split_heads(x: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, -1)


def _gqa_fold(q: jnp.ndarray, n_kv: int) -> jnp.ndarray:
    """(B,S,Hq,d) -> (B,S,Hkv,G,d)."""
    b, s, hq, d = q.shape
    return q.reshape(b, s, n_kv, hq // n_kv, d)


def _direct_attention(q, k, v, mask) -> jnp.ndarray:
    """q: (B,S,Hkv,G,d); k,v: (B,T,Hkv,d); mask: (S,T) bool or None."""
    d = q.shape[-1]
    scores = jnp.einsum("bshgd,bthd->bhgst", q, k).astype(jnp.float32)
    scores = scores / math.sqrt(d)
    if mask is not None:
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhgst,bthd->bshgd", probs, v)


def _chunk_update(carry, qc, kc, vc, mask):
    """Online-softmax update for one (q-chunk, kv-chunk) pair.

    carry = (m, l, acc): running max (B,H,G,Sq), denom, accumulator.
    """
    m, l, acc = carry
    d = qc.shape[-1]
    s = jnp.einsum("bshgd,bthd->bhgst", qc, kc).astype(jnp.float32) / math.sqrt(d)
    if mask is not None:
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + jnp.sum(p, axis=-1)
    pv = jnp.einsum("bhgst,bthd->bshgd", p.astype(qc.dtype), vc).astype(jnp.float32)
    acc_new = acc * jnp.moveaxis(corr, -1, 1)[..., None] + pv
    return m_new, l_new, acc_new


# Remat per chunk-pair: without this, the backward pass keeps every chunk's
# (B,H,G,cq,ck) score/prob residuals alive at once (O(S^2) fp32 again — the
# thing chunking exists to avoid).  Recomputing one chunk matmul in the bwd is
# the standard flash-attention trade.
_chunk_update_nomask = jax.checkpoint(lambda carry, qc, kc, vc: _chunk_update(carry, qc, kc, vc, None))
_chunk_update_masked = jax.checkpoint(_chunk_update)


def _pair_mask(i: int, j: int, chunk: int, causal: bool, window: int):
    """Static (chunk, chunk) mask for q-chunk i vs kv-chunk j, or None if the
    pair is fully allowed.  window > 0 limits lookback to ``window`` tokens."""
    idx = jnp.arange(chunk)
    qpos = i * chunk + idx[:, None]
    kpos = j * chunk + idx[None, :]
    # j == i needs the diagonal mask; j > i (only visited by the masked-full
    # baseline schedule) is fully in the future and the same mask zeroes it
    need_causal = causal and j >= i
    # farthest lookback in this pair: (i - j) * chunk + (chunk - 1)
    need_window = window > 0 and (i - j + 1) * chunk - 1 > window
    if not need_causal and not need_window:
        return None
    mask = jnp.ones((chunk, chunk), bool)
    if need_causal:
        mask &= qpos >= kpos
    if need_window:
        mask &= (qpos - kpos) <= window
    return mask


def chunked_attention(
    q, k, v, *, causal: bool, chunk: int, triangular: bool, window: int = 0
) -> jnp.ndarray:
    """Flash-style (banded) attention.  q: (B,S,Hkv,G,d); k,v: (B,T,Hkv,d).

    Python loop over q-chunks (static), lax.scan over unmasked interior
    kv-chunks.  ``triangular`` skips j > i chunks for causal attention (no
    masked-out FLOPs issued); ``window`` > 0 additionally skips chunks fully
    outside the local-attention band — O(S·W) instead of O(S²).
    """
    b, s, hkv, g, d = q.shape
    t = k.shape[1]
    assert s % chunk == 0, (s, chunk)
    t_pad = (-t) % chunk
    if t_pad:  # KV not chunk-aligned (e.g. cross-attention into a 1500-frame
        # encoder): pad and mask the tail keys out of the last chunk
        k = jnp.pad(k, ((0, 0), (0, t_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, t_pad), (0, 0), (0, 0)))
    nq, nk = s // chunk, (t + t_pad) // chunk
    valid_t = t
    k_chunks = k.reshape(b, nk, chunk, hkv, d)
    v_chunks = v.reshape(b, nk, chunk, hkv, d)

    def pair_mask(i, j):
        m = _pair_mask(i, j, chunk, causal, window)
        if t_pad and j == nk - 1:
            colm = jnp.broadcast_to(
                (j * chunk + jnp.arange(chunk))[None, :] < valid_t, (chunk, chunk)
            )
            m = colm if m is None else (m & colm)
        return m
    outs = []
    for i in range(nq):
        qc = q[:, i * chunk : (i + 1) * chunk]
        m = jnp.full((b, hkv, g, chunk), NEG_INF, jnp.float32)
        l = jnp.zeros((b, hkv, g, chunk), jnp.float32)
        acc = jnp.zeros((b, chunk, hkv, g, d), jnp.float32)
        hi = (i + 1) if (causal and triangular) else nk
        lo = 0
        if window > 0:
            lo = max(0, i - (window + chunk - 1) // chunk)
        if causal and triangular:
            masked_js = [j for j in range(lo, hi) if pair_mask(i, j) is not None]
            plain_js = [j for j in range(lo, hi) if j not in masked_js]
            if plain_js:
                # contiguous interior chunks via scan (they share no mask)
                sel_k = jnp.moveaxis(k_chunks[:, plain_js[0] : plain_js[-1] + 1], 1, 0)
                sel_v = jnp.moveaxis(v_chunks[:, plain_js[0] : plain_js[-1] + 1], 1, 0)

                def body(carry, kv):
                    kc, vc = kv
                    return _chunk_update_nomask(carry, qc, kc, vc), None

                (m, l, acc), _ = jax.lax.scan(body, (m, l, acc), (sel_k, sel_v))
            for j in masked_js:
                m, l, acc = _chunk_update_masked(
                    (m, l, acc), qc, k_chunks[:, j], v_chunks[:, j], pair_mask(i, j)
                )
        else:
            # masked-full baseline: every kv chunk in [lo, hi) visited,
            # causality/banding purely by masks (extra FLOPs issued)
            for j in range(lo, hi):
                mask = pair_mask(i, j)
                if mask is None:
                    m, l, acc = _chunk_update_nomask((m, l, acc), qc, k_chunks[:, j], v_chunks[:, j])
                else:
                    m, l, acc = _chunk_update_masked((m, l, acc), qc, k_chunks[:, j], v_chunks[:, j], mask)
        out = acc / jnp.moveaxis(l, -1, 1)[..., None]
        outs.append(out.astype(q.dtype))
    return jnp.concatenate(outs, axis=1)


def full_attention(q, k, v, *, causal: bool, chunk: int, triangular: bool, flash_threshold: int, window: int = 0) -> jnp.ndarray:
    """Entry point.  q: (B,S,Hq,d) -> (B,S,Hq,d); k,v: (B,T,Hkv,d)."""
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    qf = _gqa_fold(q, hkv)
    if s <= flash_threshold and k.shape[1] <= flash_threshold and not window:
        mask = None
        if causal:
            t = k.shape[1]
            mask = (jnp.arange(s)[:, None] + (t - s)) >= jnp.arange(t)[None, :]
        out = _direct_attention(qf, k, v, mask)
    else:
        cw = min(chunk, s)
        out = chunked_attention(
            qf, k, v, causal=causal, chunk=cw, triangular=triangular, window=window
        )
    return out.reshape(b, s, hq, d)


def local_attention(q, k, v, window: int) -> jnp.ndarray:
    """Causal windowed attention: each query sees the previous ``window``
    tokens.  q: (B,S,Hq,d), k/v: (B,S,Hkv,d).  Implemented as chunked
    attention over (previous, self) chunks with chunk == window: O(S·W).
    """
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    w = min(window, s)
    pad = (-s) % w
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = s + pad
    n = sp // w
    qf = _gqa_fold(q, hkv).reshape(b, n, w, hkv, hq // hkv, d)
    kc = k.reshape(b, n, w, hkv, d)
    vc = v.reshape(b, n, w, hkv, d)
    # keys: previous chunk ++ self chunk
    kprev = jnp.pad(kc, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    vprev = jnp.pad(vc, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    kk = jnp.concatenate([kprev, kc], axis=2)  # (b,n,2w,hkv,d)
    vv = jnp.concatenate([vprev, vc], axis=2)
    qpos = jnp.arange(w)[:, None] + w  # position within 2w frame
    kpos = jnp.arange(2 * w)[None, :]
    mask = (qpos >= kpos) & (qpos - kpos < w + 1)  # (w, 2w)
    # chunk 0 has no real previous chunk — its first-w frame is zero padding
    is_first = (jnp.arange(n) == 0)[:, None, None]
    mask = mask[None] & ~(is_first & (kpos < w)[None])  # (n, w, 2w)
    # dims: s = w queries, t = 2w keys, h = hkv groups, g = q-per-kv
    scores = jnp.einsum("bnshgd,bnthd->bnhgst", qf, kk).astype(jnp.float32) / math.sqrt(d)
    scores = jnp.where(mask[None, :, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bnhgst,bnthd->bnshgd", probs, vv)
    out = out.reshape(b, sp, hq, d)
    return out[:, :s]


def _kv_qmax(spec: PrecisionSpec) -> int:
    """The int8 cache stores 8-bit payloads; narrower specs use fewer of
    those bits (adaptive precision), wider ones would silently saturate."""
    if spec.act_bits > 8:
        raise ValueError(
            f"int8 KV cache holds at most 8-bit payloads, got act_bits={spec.act_bits}"
        )
    return 2 ** (spec.act_bits - 1) - 1


def quantize_kv(x: jnp.ndarray, spec: PrecisionSpec = PrecisionSpec.int8):
    """Per-(b, t, h) symmetric integer quantization of a (B,T,H,d) tensor —
    PIMSAB adaptive precision on decode state (``spec.act_bits`` wide)."""
    qmax = _kv_qmax(spec)
    xf = x.astype(jnp.float32)
    s = jnp.maximum(jnp.max(jnp.abs(xf), axis=-1) / qmax, 1e-8)  # (B,T,H)
    xq = jnp.clip(jnp.round(xf / s[..., None]), -qmax, qmax).astype(jnp.int8)
    return xq, s


def decode_attention_int8(
    q1, k_q, v_q, k_s, v_s, valid_len=None, spec: PrecisionSpec = PrecisionSpec.int8
) -> jnp.ndarray:
    """Integer decode attention (PIMSAB bit-serial attention on the MXU):
    scores and readout run int8×int8→int32; scales re-applied afterwards.

    q1: (B,1,Hq,d) float; k_q/v_q: (B,T,Hkv,d) int8; k_s/v_s: (B,T,Hkv) f32.
    """
    qmax = _kv_qmax(spec)
    b, _, hq, d = q1.shape
    hkv = k_q.shape[2]
    qf = _gqa_fold(q1, hkv)[:, 0].astype(jnp.float32)  # (B,Hkv,G,d)
    qs = jnp.maximum(jnp.max(jnp.abs(qf), axis=-1) / qmax, 1e-8)  # (B,Hkv,G)
    qq = jnp.clip(jnp.round(qf / qs[..., None]), -qmax, qmax).astype(jnp.int8)
    iscores = jnp.einsum("bhgd,bthd->bhgt", qq, k_q, preferred_element_type=jnp.int32)
    scores = iscores.astype(jnp.float32) * qs[..., None] * jnp.moveaxis(k_s, 1, -1)[:, :, None]
    scores = scores / math.sqrt(d)
    if valid_len is not None:
        t = k_q.shape[1]
        scores = jnp.where(
            jnp.arange(t)[None, None, None] < valid_len[:, None, None, None], scores, NEG_INF
        )
    probs = jax.nn.softmax(scores, axis=-1)
    # fold the per-row v-scale into the probabilities (both per (b,t,h)),
    # then one int8-payload contraction — no bf16 cache materialization
    pw = probs * jnp.moveaxis(v_s, 1, -1)[:, :, None]  # (B,Hkv,G,T)
    out = jnp.einsum("bhgt,bthd->bhgd", pw, v_q.astype(jnp.float32))
    return out.reshape(b, 1, hq, d).astype(q1.dtype)


def decode_attention(q1, k_cache, v_cache, valid_len: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """One-token decode: q1 (B,1,Hq,d) vs cache (B,T,Hkv,d)."""
    b, _, hq, d = q1.shape
    hkv = k_cache.shape[2]
    qf = _gqa_fold(q1, hkv)[:, 0]  # (B,Hkv,G,d)
    scores = jnp.einsum("bhgd,bthd->bhgt", qf, k_cache).astype(jnp.float32)
    scores = scores / math.sqrt(d)
    if valid_len is not None:
        t = k_cache.shape[1]
        scores = jnp.where(jnp.arange(t)[None, None, None] < valid_len[:, None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q1.dtype)
    out = jnp.einsum("bhgt,bthd->bhgd", probs, v_cache)
    return out.reshape(b, 1, hq, d)
