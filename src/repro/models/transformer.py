"""Composable transformer: one model assembly covering all 10 assigned
architectures (dense GQA, MoE, RG-LRU hybrid, xLSTM, enc-dec audio, VLM).

The layer stack is the config's ``block_pattern`` tiled to ``n_layers`` and
executed as ``lax.scan`` over *pattern groups* (params stacked on a leading
group axis) so the HLO stays depth-independent.  Three entry points:

* ``forward``     — full-sequence logits (training / evaluation).
* ``prefill``     — full-sequence forward that also returns the decode cache.
* ``decode_step`` — one token in, one token out, cache updated in place.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.api import PrecisionSpec
from repro.models import frontend
from repro.models.attention import (
    decode_attention,
    full_attention,
    local_attention,
)
from repro.models.common import (
    Params,
    apply_rope,
    dense_init,
    dtype_of,
    linear,
    linear_init,
    rmsnorm,
    rmsnorm_init,
    softmax_cross_entropy,
    swiglu,
)
from repro.models.moe import moe_ffn, moe_init
from repro.models.recurrent import (
    CONV_K,
    mlstm_block_apply,
    mlstm_full_state_init,
    rglru_block_apply,
    rglru_state_init,
    slstm_block_apply,
    slstm_state_init,
)
from repro.models.runtime import DEFAULT_FLAGS, RunFlags
from repro.dist.sharding import MeshRules, act_spec, cache_entry_spec, constrain

# Decode-state precision (PIMSAB adaptive precision on the KV cache): the
# int8 preset matches the MXU's native slice width — one plane pair per
# score/readout contraction.  A future RunFlags lever can lower this.
KV_SPEC = PrecisionSpec.int8

# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _attn_init(key, cfg, dtype, cross: bool = False) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": linear_init(ks[0], d, cfg.q_dim, dtype, bias=cfg.qkv_bias),
        "wk": linear_init(ks[1], d, cfg.kv_dim, dtype, bias=cfg.qkv_bias),
        "wv": linear_init(ks[2], d, cfg.kv_dim, dtype, bias=cfg.qkv_bias),
        "wo": linear_init(ks[3], cfg.q_dim, d, dtype),
    }
    return p


def _ffn_init(key, cfg, dtype) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w_gate": linear_init(ks[0], d, f, dtype),
        "w_up": linear_init(ks[1], d, f, dtype),
        "w_down": linear_init(ks[2], f, d, dtype),
    }


def _block_init(key, cfg, kind: str, dtype, decoder: bool) -> Params:
    """One block = norm + temporal mixer (+ cross-attn) (+ norm + FFN)."""
    ks = jax.random.split(key, 4)
    p: Params = {"ln1": rmsnorm_init(cfg.d_model, dtype)}
    if kind in ("attn", "local_attn"):
        p["attn"] = _attn_init(ks[0], cfg, dtype)
    elif kind == "rglru":
        from repro.models.recurrent import rglru_block_init

        p["mixer"] = rglru_block_init(ks[0], cfg, dtype)
    elif kind == "mlstm":
        from repro.models.recurrent import mlstm_block_init

        p["mixer"] = mlstm_block_init(ks[0], cfg, dtype)
    elif kind == "slstm":
        from repro.models.recurrent import slstm_block_init

        p["mixer"] = slstm_block_init(ks[0], cfg, dtype)
    else:
        raise ValueError(kind)
    if decoder and cfg.is_encdec:
        p["lnx"] = rmsnorm_init(cfg.d_model, dtype)
        p["cross"] = _attn_init(ks[1], cfg, dtype)
    if cfg.d_ff > 0 and kind in ("attn", "local_attn", "rglru"):
        p["ln2"] = rmsnorm_init(cfg.d_model, dtype)
        p["ffn"] = moe_init(ks[2], cfg, dtype) if cfg.is_moe else _ffn_init(ks[2], cfg, dtype)
    return p


def _stack_groups(key, cfg, dtype, n_groups: int, pattern, decoder: bool) -> Params:
    """Init per group then stack leaves on a leading (G, ...) axis."""
    gkeys = jax.random.split(key, n_groups)

    def one_group(k):
        pk = jax.random.split(k, len(pattern))
        return {
            f"{i:02d}_{kind}": _block_init(pk[i], cfg, kind, dtype, decoder)
            for i, kind in enumerate(pattern)
        }

    groups = [one_group(k) for k in gkeys]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *groups)


def init_params(key: jax.Array, cfg: ModelConfig) -> Params:
    dtype = dtype_of(cfg)
    ks = jax.random.split(key, 8)
    vp = cfg.padded_vocab()
    params: Params = {
        "embed": {"w": dense_init(ks[0], vp, cfg.d_model, dtype, scale=0.02)},
        "blocks": _stack_groups(
            ks[1], cfg, dtype, cfg.pattern_groups(), cfg.block_pattern, decoder=True
        ),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {"w": dense_init(ks[2], cfg.d_model, vp, dtype, scale=0.02)}
    if cfg.is_encdec:
        params["enc_blocks"] = _stack_groups(
            ks[3], cfg, dtype, cfg.n_enc_layers, ("attn",), decoder=False
        )
        params["enc_norm"] = rmsnorm_init(cfg.d_model, dtype)
        params["audio_adapter"] = frontend.audio_adapter_init(ks[4], cfg, dtype)
    if cfg.frontend == "vision":
        params["vision_adapter"] = frontend.vision_adapter_init(ks[5], cfg, dtype)
    return params


def params_shape(cfg: ModelConfig) -> Params:
    """ShapeDtypeStruct tree, no allocation (for the dry-run)."""
    return jax.eval_shape(lambda: init_params(jax.random.key(0), cfg))


def param_bytes(tree) -> int:
    return sum(
        int(np_prod(l.shape)) * l.dtype.itemsize for l in jax.tree_util.tree_leaves(tree)
    )


def np_prod(shape) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out


# ---------------------------------------------------------------------------
# block application (sequence form)
# ---------------------------------------------------------------------------


def _attn_apply(
    p: Params,
    x: jnp.ndarray,
    cfg: ModelConfig,
    flags: RunFlags,
    positions: jnp.ndarray,
    kind: str,
    causal: bool,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = linear(p["wq"], x).reshape(b, s, cfg.n_heads, hd)
    k = linear(p["wk"], x).reshape(b, s, cfg.n_kv_heads, hd)
    v = linear(p["wv"], x).reshape(b, s, cfg.n_kv_heads, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if kind == "local_attn":
        if s <= 2 * cfg.window and s <= flags.flash_threshold:
            out = local_attention(q, k, v, cfg.window)  # small-S direct band
        else:
            out = full_attention(
                q, k, v,
                causal=causal,
                chunk=min(flags.attn_chunk, cfg.window),
                triangular=flags.triangular_attn,
                flash_threshold=0,  # always banded-chunked
                window=cfg.window,
            )
    else:
        out = full_attention(
            q,
            k,
            v,
            causal=causal,
            chunk=flags.attn_chunk,
            triangular=flags.triangular_attn,
            flash_threshold=flags.flash_threshold,
        )
    y = linear(p["wo"], out.reshape(b, s, cfg.q_dim))
    return y, {"k": k, "v": v}


def _cross_apply(p: Params, x: jnp.ndarray, enc_kv: Dict[str, jnp.ndarray], cfg) -> jnp.ndarray:
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = linear(p["wq"], x).reshape(b, s, cfg.n_heads, hd)
    out = full_attention(
        q, enc_kv["k"], enc_kv["v"], causal=False, chunk=2048, triangular=False, flash_threshold=8192
    )
    return linear(p["wo"], out.reshape(b, s, cfg.q_dim))


def _cross_kv(p: Params, enc_out: jnp.ndarray, cfg) -> Dict[str, jnp.ndarray]:
    b, t, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    return {
        "k": linear(p["wk"], enc_out).reshape(b, t, cfg.n_kv_heads, hd),
        "v": linear(p["wv"], enc_out).reshape(b, t, cfg.n_kv_heads, hd),
    }


def _ffn_apply(p: Params, x: jnp.ndarray, cfg, flags: RunFlags, rules) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if cfg.is_moe:
        groups = flags.routing_groups or (rules.dp if rules is not None else 1)
        tokens = x.shape[0] * x.shape[1]
        while tokens % groups:
            groups -= 1
        return moe_ffn(p, x, cfg, groups)
    return linear(p["w_down"], swiglu(linear(p["w_gate"], x), linear(p["w_up"], x))), jnp.float32(0)


def _block_apply_seq(
    p: Params,
    x: jnp.ndarray,
    kind: str,
    cfg: ModelConfig,
    flags: RunFlags,
    rules: Optional[MeshRules],
    positions: jnp.ndarray,
    enc_out: Optional[jnp.ndarray],
    causal: bool,
    states: Optional[Params] = None,
) -> Tuple[jnp.ndarray, Params, jnp.ndarray]:
    """Returns (x_out, new_cache_entries, aux_loss)."""
    aux = jnp.float32(0)
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    cache_out: Params = {}
    if kind in ("attn", "local_attn"):
        y, kv = _attn_apply(p["attn"], h, cfg, flags, positions, kind, causal)
        cache_out.update(kv)
    elif kind == "rglru":
        y, st = rglru_block_apply(p["mixer"], h, cfg, states)
        cache_out.update(st)
    elif kind == "mlstm":
        y, st = mlstm_block_apply(p["mixer"], h, cfg, states, chunk=flags.attn_chunk if flags.attn_chunk <= 256 else 256)
        cache_out.update(st)
    elif kind == "slstm":
        y, st = slstm_block_apply(p["mixer"], h, cfg, states)
        cache_out.update(st)
    x = x + y
    if "cross" in p and enc_out is not None:
        hx = rmsnorm(p["lnx"], x, cfg.norm_eps)
        kvx = _cross_kv(p["cross"], enc_out, cfg)
        x = x + _cross_apply(p["cross"], hx, kvx, cfg)
        cache_out["cross_k"], cache_out["cross_v"] = kvx["k"], kvx["v"]
    if "ffn" in p:
        h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
        y2, a = _ffn_apply(p["ffn"], h2, cfg, flags, rules)
        x = x + y2
        aux = aux + a
    return x, cache_out, aux


# ---------------------------------------------------------------------------
# forward (train / no-cache evaluation)
# ---------------------------------------------------------------------------


def _embed_tokens(params: Params, tokens: jnp.ndarray, cfg) -> jnp.ndarray:
    x = params["embed"]["w"][tokens]
    return x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)


def _run_encoder(params: Params, cfg, flags, rules, frame_embeds: jnp.ndarray) -> jnp.ndarray:
    x = frontend.embed_frames(params["audio_adapter"], frame_embeds.astype(dtype_of(cfg)))
    t = x.shape[1]
    positions = jnp.arange(t)[None]

    def body(carry, gp):
        h, _, _ = _block_apply_seq(
            gp["00_attn"], carry, "attn", cfg, flags, rules, positions, None, causal=False
        )
        return h, None

    if flags.scan_layers:
        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    else:
        for gi in range(cfg.n_enc_layers):
            gp = jax.tree_util.tree_map(lambda l: l[gi], params["enc_blocks"])
            x, _ = body(x, gp)
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def forward(
    params: Params,
    cfg: ModelConfig,
    batch: Dict[str, jnp.ndarray],
    flags: RunFlags = DEFAULT_FLAGS,
    rules: Optional[MeshRules] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence logits.  Returns (logits, aux_loss)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = _embed_tokens(params, tokens, cfg)
    if cfg.frontend == "vision" and "patch_embeds" in batch:
        x = frontend.fuse_patches(params["vision_adapter"], x, batch["patch_embeds"])
    x = constrain(x, rules, act_spec(b, rules) if rules else None)
    enc_out = None
    if cfg.is_encdec:
        enc_out = _run_encoder(params, cfg, flags, rules, batch["enc_embeds"])
    positions = jnp.arange(s)[None]
    pattern = cfg.block_pattern

    def one_block(pb, xx, pos_arg, enc_arg, kind):
        out, _, a = _block_apply_seq(
            pb, xx, kind, cfg, flags, rules, pos_arg, enc_arg, causal=True
        )
        return out, a

    # Remat per *block* (not per pattern group): a group can be 13 layers
    # (recurrentgemma) and rematerializing it whole keeps every layer's
    # intermediates live in the backward at once.
    blocked = {
        kind: (jax.checkpoint(partial(one_block, kind=kind)) if flags.remat else partial(one_block, kind=kind))
        for kind in set(pattern)
    }

    def group_body(carry, gp):
        x, aux = carry
        for i, kind in enumerate(pattern):
            x, a = blocked[kind](gp[f"{i:02d}_{kind}"], x, positions, enc_out)
            aux = aux + a
        x = constrain(x, rules, act_spec(b, rules) if rules else None)
        return (x, aux), None

    if flags.scan_layers:
        (x, aux), _ = jax.lax.scan(group_body, (x, jnp.float32(0)), params["blocks"])
    else:
        carry = (x, jnp.float32(0))
        g = cfg.pattern_groups()
        for gi in range(g):
            gp = jax.tree_util.tree_map(lambda l: l[gi], params["blocks"])
            carry, _ = group_body(carry, gp)
        x, aux = carry
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = _lm_head(params, x, cfg)
    return logits, aux


def _lm_head(params: Params, x: jnp.ndarray, cfg) -> jnp.ndarray:
    if cfg.tie_embeddings:
        return x @ params["embed"]["w"].T
    return linear(params["lm_head"], x)  # handles the int8 bit-sliced head


def loss_fn(params, cfg, batch, flags=DEFAULT_FLAGS, rules=None):
    logits, aux = forward(params, cfg, batch, flags, rules)
    ce = softmax_cross_entropy(logits, batch["labels"], cfg.vocab_size)
    return ce + 0.01 * aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# KV / state cache
# ---------------------------------------------------------------------------


def _cache_entry_shape(cfg, kind: str, batch: int, max_len: int, flags=DEFAULT_FLAGS) -> Dict[str, Any]:
    hd, hkv = cfg.resolved_head_dim, cfg.n_kv_heads
    dt = dtype_of(cfg)

    def kv_entry(length):
        shp = (batch, length, hkv, hd)
        if flags.quant_kv:
            # PIMSAB adaptive precision on state: int8 payload + per-(b,t,h)
            # scales; scores/readout run on the integer path (bit-serial attn)
            return {
                "k": jnp.zeros(shp, jnp.int8),
                "v": jnp.zeros(shp, jnp.int8),
                "k_scale": jnp.zeros((batch, length, hkv), jnp.float32),
                "v_scale": jnp.zeros((batch, length, hkv), jnp.float32),
            }
        return {"k": jnp.zeros(shp, dt), "v": jnp.zeros(shp, dt)}

    if kind == "attn":
        entry = kv_entry(max_len)
    elif kind == "local_attn":
        entry = kv_entry(min(cfg.window, max_len))
    elif kind == "rglru":
        entry = dict(rglru_state_init(cfg, batch))
    elif kind == "mlstm":
        entry = dict(mlstm_full_state_init(cfg, batch))
    elif kind == "slstm":
        entry = dict(slstm_state_init(cfg, batch))
    else:
        raise ValueError(kind)
    if cfg.is_encdec and kind == "attn":
        xshp = (batch, cfg.enc_seq_len, hkv, hd)
        entry["cross_k"] = jnp.zeros(xshp, dt)
        entry["cross_v"] = jnp.zeros(xshp, dt)
    return entry


def init_cache(cfg: ModelConfig, batch: int, max_len: int, flags: RunFlags = DEFAULT_FLAGS) -> Params:
    """Decode cache: stacked (G, ...) per pattern position + position scalar."""

    def stacked(kind):
        g = cfg.pattern_groups()
        entry = _cache_entry_shape(cfg, kind, batch, max_len, flags)
        return jax.tree_util.tree_map(lambda l: jnp.broadcast_to(l, (g,) + l.shape), entry)

    return {
        "pos": jnp.zeros((), jnp.int32),
        "blocks": {
            f"{i:02d}_{kind}": stacked(kind) for i, kind in enumerate(cfg.block_pattern)
        },
    }


def cache_shape(cfg: ModelConfig, batch: int, max_len: int, flags: RunFlags = DEFAULT_FLAGS) -> Params:
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len, flags))


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------


def prefill(
    params: Params,
    cfg: ModelConfig,
    batch: Dict[str, jnp.ndarray],
    flags: RunFlags = DEFAULT_FLAGS,
    rules: Optional[MeshRules] = None,
    max_len: Optional[int] = None,
) -> Tuple[Params, jnp.ndarray]:
    """Run the prompt, return (cache, last-token logits)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    max_len = max_len or s
    x = _embed_tokens(params, tokens, cfg)
    if cfg.frontend == "vision" and "patch_embeds" in batch:
        x = frontend.fuse_patches(params["vision_adapter"], x, batch["patch_embeds"])
    x = constrain(x, rules, act_spec(b, rules) if rules else None)
    enc_out = None
    if cfg.is_encdec:
        enc_out = _run_encoder(params, cfg, flags, rules, batch["enc_embeds"])
    positions = jnp.arange(s)[None]
    pattern = cfg.block_pattern

    def group_body(x, gp):
        entries = {}
        for i, kind in enumerate(pattern):
            x, cache_new, _ = _block_apply_seq(
                gp[f"{i:02d}_{kind}"], x, kind, cfg, flags, rules, positions, enc_out, causal=True
            )
            entries[f"{i:02d}_{kind}"] = _seq_cache_to_decode_cache(
                cache_new, kind, cfg, s, max_len, flags
            )
        x = constrain(x, rules, act_spec(b, rules) if rules else None)
        return x, entries

    if flags.scan_layers:
        x, stacked_entries = jax.lax.scan(group_body, x, params["blocks"])
    else:  # unrolled (cost-analysis correction path / perf experiments)
        entries_list = []
        for gi in range(cfg.pattern_groups()):
            gp = jax.tree_util.tree_map(lambda l: l[gi], params["blocks"])
            x, e = group_body(x, gp)
            entries_list.append(e)
        stacked_entries = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *entries_list)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = _lm_head(params, x[:, -1:], cfg)[:, 0]
    cache = {"pos": jnp.asarray(s, jnp.int32), "blocks": stacked_entries}
    return cache, logits


def _seq_cache_to_decode_cache(
    entries: Params, kind: str, cfg, s: int, max_len: int, flags: RunFlags = DEFAULT_FLAGS
) -> Params:
    """Convert full-sequence block outputs into decode-cache layout."""
    from repro.models.attention import quantize_kv

    def finish(kv_dict):
        if not flags.quant_kv:
            return kv_dict
        out = {}
        for n in ("k", "v"):
            q, sc = quantize_kv(kv_dict[n], KV_SPEC)
            out[n], out[f"{n}_scale"] = q, sc
        for n in ("cross_k", "cross_v"):
            if n in kv_dict:
                out[n] = kv_dict[n]
        return out

    if kind == "attn":
        out = {}
        for n in ("k", "v"):
            kv = entries[n]  # (B,S,Hkv,hd)
            pad = max_len - s
            if pad > 0:
                kv = jnp.pad(kv, ((0, 0), (0, pad), (0, 0), (0, 0)))
            out[n] = kv
        for n in ("cross_k", "cross_v"):
            if n in entries:
                out[n] = entries[n]
        return finish(out)
    if kind == "local_attn":
        w = min(cfg.window, max_len)
        out = {}
        for n in ("k", "v"):
            kv = entries[n]
            if s >= w:
                out[n] = kv[:, s - w : s]
            else:
                out[n] = jnp.pad(kv, ((0, 0), (0, w - s), (0, 0), (0, 0)))
        return finish(out)
    # recurrent kinds: states pass through
    return dict(entries)


# ---------------------------------------------------------------------------
# decode step
# ---------------------------------------------------------------------------


def _attn_decode(p, h, cfg, entry, pos, kind, rules):
    from repro.models.attention import decode_attention_int8, quantize_kv

    b = h.shape[0]
    hd = cfg.resolved_head_dim
    q = linear(p["wq"], h).reshape(b, 1, cfg.n_heads, hd)
    k = linear(p["wk"], h).reshape(b, 1, cfg.n_kv_heads, hd)
    v = linear(p["wv"], h).reshape(b, 1, cfg.n_kv_heads, hd)
    posb = jnp.full((b, 1), pos, jnp.int32)
    q = apply_rope(q, posb, cfg.rope_theta)
    k = apply_rope(k, posb, cfg.rope_theta)
    if kind == "local_attn":
        w = entry["k"].shape[1]
        slot = pos % w
        valid = jnp.minimum(pos + 1, w) * jnp.ones((b,), jnp.int32)
        # ring buffer: all slots < valid are live (order irrelevant w/ RoPE
        # applied at insert time)
    else:
        slot = pos
        valid = (pos + 1) * jnp.ones((b,), jnp.int32)
    new_entry = dict(entry)
    if "k_scale" in entry:  # int8 KV cache (PIMSAB adaptive precision)
        kq, ks = quantize_kv(k, KV_SPEC)
        vq, vs = quantize_kv(v, KV_SPEC)
        new_entry["k"] = jax.lax.dynamic_update_slice_in_dim(entry["k"], kq, slot, axis=1)
        new_entry["v"] = jax.lax.dynamic_update_slice_in_dim(entry["v"], vq, slot, axis=1)
        new_entry["k_scale"] = jax.lax.dynamic_update_slice_in_dim(entry["k_scale"], ks, slot, axis=1)
        new_entry["v_scale"] = jax.lax.dynamic_update_slice_in_dim(entry["v_scale"], vs, slot, axis=1)
        out = decode_attention_int8(
            q, new_entry["k"], new_entry["v"], new_entry["k_scale"], new_entry["v_scale"],
            valid, KV_SPEC,
        )
    else:
        new_entry["k"] = jax.lax.dynamic_update_slice_in_dim(entry["k"], k, slot, axis=1)
        new_entry["v"] = jax.lax.dynamic_update_slice_in_dim(entry["v"], v, slot, axis=1)
        out = decode_attention(q, new_entry["k"], new_entry["v"], valid)
    y = linear(p["wo"], out.reshape(b, 1, cfg.q_dim))
    return y, new_entry


def decode_step(
    params: Params,
    cfg: ModelConfig,
    cache: Params,
    tokens: jnp.ndarray,
    flags: RunFlags = DEFAULT_FLAGS,
    rules: Optional[MeshRules] = None,
) -> Tuple[Params, jnp.ndarray]:
    """tokens: (B, 1).  Returns (new_cache, logits (B, vocab))."""
    b = tokens.shape[0]
    pos = cache["pos"]
    x = _embed_tokens(params, tokens, cfg)
    pattern = cfg.block_pattern

    def group_body(x, scan_in):
        gp, gcache = scan_in
        new_entries = {}
        for i, kind in enumerate(pattern):
            key = f"{i:02d}_{kind}"
            p, entry = gp[key], gcache[key]
            h = rmsnorm(p["ln1"], x, cfg.norm_eps)
            if kind in ("attn", "local_attn"):
                y, new_entry = _attn_decode(p["attn"], h, cfg, entry, pos, kind, rules)
            elif kind == "rglru":
                y, st = rglru_block_apply(p["mixer"], h, cfg, entry)
                new_entry = st
            elif kind == "mlstm":
                y, st = mlstm_block_apply(p["mixer"], h, cfg, entry)
                new_entry = st
            elif kind == "slstm":
                y, st = slstm_block_apply(p["mixer"], h, cfg, entry)
                new_entry = st
            x = x + y
            if "cross" in p:
                hx = rmsnorm(p["lnx"], x, cfg.norm_eps)
                enc_kv = {"k": entry["cross_k"], "v": entry["cross_v"]}
                xq = linear(p["cross"]["wq"], hx).reshape(b, 1, cfg.n_heads, cfg.resolved_head_dim)
                out = decode_attention(xq, enc_kv["k"], enc_kv["v"])
                x = x + linear(p["cross"]["wo"], out.reshape(b, 1, cfg.q_dim))
                new_entry["cross_k"], new_entry["cross_v"] = entry["cross_k"], entry["cross_v"]
            if "ffn" in p:
                h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
                y2, _ = _ffn_apply(p["ffn"], h2, cfg, flags, rules)
                x = x + y2
            new_entries[key] = new_entry
        return x, new_entries

    if flags.scan_layers:
        x, new_blocks = jax.lax.scan(group_body, x, (params["blocks"], cache["blocks"]))
    else:
        blocks_list = []
        for gi in range(cfg.pattern_groups()):
            gp = jax.tree_util.tree_map(lambda l: l[gi], params["blocks"])
            gc = jax.tree_util.tree_map(lambda l: l[gi], cache["blocks"])
            x, nb = group_body(x, (gp, gc))
            blocks_list.append(nb)
        new_blocks = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *blocks_list)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = _lm_head(params, x, cfg)[:, 0]
    new_cache = {"pos": pos + 1, "blocks": new_blocks}
    return new_cache, logits
