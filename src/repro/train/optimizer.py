"""AdamW with bf16 params + fp32 master/moments, and the WSD
(warmup-stable-decay) schedule MiniCPM trains with.

Hand-rolled on pytrees (no optax dependency).  Optimizer state:
``{"m", "v", "master", "count"}`` — ``master`` holds fp32 weights when params
are low-precision (mixed-precision training standard practice).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    keep_master: bool = True


def adamw_init(params: Any, cfg: AdamWConfig) -> Any:
    f32 = lambda l: jnp.zeros(l.shape, jnp.float32)
    state = {
        "m": jax.tree_util.tree_map(f32, params),
        "v": jax.tree_util.tree_map(f32, params),
        "count": jnp.zeros((), jnp.int32),
    }
    if cfg.keep_master:
        state["master"] = jax.tree_util.tree_map(lambda l: l.astype(jnp.float32), params)
    return state


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    grads: Any, state: Any, params: Any, cfg: AdamWConfig, lr: jnp.ndarray
) -> Tuple[Any, Any]:
    count = state["count"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-12))
    bc1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    source = state["master"] if "master" in state else params

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        step = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (step + cfg.weight_decay * pf)
        return m, v, pf

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_p = treedef.flatten_up_to(source)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_m = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_masters = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    dtypes = jax.tree_util.tree_map(lambda l: l.dtype, params)
    new_params = jax.tree_util.tree_map(lambda f, dt: f.astype(dt), new_masters, dtypes)
    new_state = {"m": new_m, "v": new_v, "count": count}
    if "master" in state:
        new_state["master"] = new_masters
    return new_params, new_state


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------


def wsd_schedule(
    base_lr: float, warmup: int, stable: int, decay: int, floor: float = 0.1
) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """Warmup-Stable-Decay (MiniCPM): linear warmup → constant → exp decay."""

    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * jnp.minimum((step + 1.0) / max(warmup, 1), 1.0)
        in_decay = jnp.maximum(step - warmup - stable, 0.0)
        frac = jnp.minimum(in_decay / max(decay, 1), 1.0)
        decayed = base_lr * (floor ** frac)
        return jnp.where(step < warmup + stable, warm, decayed)

    return lr


def cosine_schedule(base_lr: float, warmup: int, total: int, floor_frac: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * jnp.minimum((step + 1.0) / max(warmup, 1), 1.0)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor_frac + (1 - floor_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, base_lr * cos)

    return lr


def schedule_for(cfg, base_lr: float = 3e-4, total_steps: int = 10_000):
    if getattr(cfg, "wsd_schedule", False):
        return wsd_schedule(base_lr, total_steps // 100 + 1, int(total_steps * 0.8), int(total_steps * 0.19) + 1)
    return cosine_schedule(base_lr, total_steps // 100 + 1, total_steps)
