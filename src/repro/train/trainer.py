"""The training loop: data pipeline + pjit step + checkpoint/restart +
heartbeat, wired together.  Runs real steps on CPU for the examples/tests
(tiny configs) and is the same loop the multi-pod launcher drives.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, TokenPipeline, batch_at
from repro.dist.sharding import MeshRules
from repro.models.runtime import DEFAULT_FLAGS, RunFlags
from repro.models.transformer import init_params
from repro.train import checkpoint
from repro.train.fault import HeartbeatMonitor
from repro.train.optimizer import AdamWConfig
from repro.train.steps import make_train_state, make_train_step


@dataclass
class TrainLoopConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    base_lr: float = 3e-4
    seed: int = 0
    # LR schedule horizon; fixed independently of `steps` so an interrupted
    # run resumed with a different --steps sees identical per-step LRs
    schedule_steps: Optional[int] = None


def train(
    cfg: ModelConfig,
    data_cfg: DataConfig,
    loop: TrainLoopConfig,
    flags: RunFlags = DEFAULT_FLAGS,
    rules: Optional[MeshRules] = None,
    resume: bool = True,
) -> Dict[str, Any]:
    """Train; returns {'state', 'history', 'resumed_from'}."""
    opt_cfg = AdamWConfig(lr=loop.base_lr)
    step_fn = make_train_step(
        cfg, flags, rules, opt_cfg,
        base_lr=loop.base_lr, total_steps=loop.schedule_steps or loop.steps,
    )
    step_fn = jax.jit(step_fn, donate_argnums=(0,))

    start_step, extra = 0, {}
    state = None
    if resume and loop.ckpt_dir and checkpoint.latest_step(loop.ckpt_dir) is not None:
        template = jax.eval_shape(
            lambda: make_train_state(init_params(jax.random.key(loop.seed), cfg), opt_cfg)
        )
        state, start_step, extra = checkpoint.restore(loop.ckpt_dir, template)
        resumed = start_step
    else:
        params = init_params(jax.random.key(loop.seed), cfg)
        state = make_train_state(params, opt_cfg)
        resumed = None

    pipe = TokenPipeline(data_cfg, start_step=extra.get("data_step", start_step))
    monitor = HeartbeatMonitor(n_workers=1)
    history = []
    t_last = time.time()
    try:
        for i in range(start_step, loop.steps):
            batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
            state, metrics = step_fn(state, batch)
            monitor.beat(0, i)
            if (i + 1) % loop.log_every == 0 or i == loop.steps - 1:
                loss = float(metrics["loss"])
                dt = (time.time() - t_last) / loop.log_every
                t_last = time.time()
                history.append({"step": i + 1, "loss": loss, "s_per_step": dt})
            if loop.ckpt_dir and ((i + 1) % loop.ckpt_every == 0 or i == loop.steps - 1):
                checkpoint.save(loop.ckpt_dir, state, i + 1, extra={"data_step": pipe.state()})
                checkpoint.prune(loop.ckpt_dir)
    finally:
        pipe.close()
    return {"state": state, "history": history, "resumed_from": resumed}
