"""Checkpointing: sharded-agnostic save/restore with atomic commit and
elastic re-sharding.

Format: one .npy per leaf + a JSON manifest (paths, shapes, dtypes, step,
data-pipeline cursor).  Writes go to a temp dir that is atomically renamed —
a crash mid-save never corrupts the latest checkpoint.  ``restore`` places
leaves onto *whatever mesh/sharding the caller passes*, so a checkpoint taken
on 2×16×16 restores cleanly onto 16×16 (elastic downscale) or a future
larger mesh: device placement is decoupled from the serialized bytes.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from pathlib import Path
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree: Any):
    flat = jax.tree_util.tree_flatten_with_path(tree)
    leaves, treedef = flat
    out = []
    for path, leaf in leaves:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out.append((key, leaf))
    return out, treedef


def save(ckpt_dir: str, state: Any, step: int, extra: Optional[Dict] = None) -> str:
    """Write checkpoint ``step`` atomically; returns the final path."""
    base = Path(ckpt_dir)
    base.mkdir(parents=True, exist_ok=True)
    final = base / f"step_{step:08d}"
    tmp = Path(tempfile.mkdtemp(dir=base, prefix=".tmp_"))
    leaves, _ = _flatten_with_paths(state)
    manifest = {"step": step, "extra": extra or {}, "leaves": []}
    for key, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        fname = key.replace("/", "__") + ".npy"
        logical = str(arr.dtype)
        if arr.dtype.kind not in "biufc":  # extension dtypes (bfloat16, fp8):
            arr = arr.view(np.uint8 if arr.dtype.itemsize == 1 else np.uint16)
        np.save(tmp / fname, arr)
        manifest["leaves"].append({"key": key, "file": fname,
                                   "shape": list(arr.shape), "dtype": logical})
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic commit
    return str(final)


def latest_step(ckpt_dir: str) -> Optional[int]:
    base = Path(ckpt_dir)
    if not base.exists():
        return None
    steps = sorted(
        int(p.name.split("_")[1]) for p in base.iterdir()
        if p.is_dir() and p.name.startswith("step_") and (p / "manifest.json").exists()
    )
    return steps[-1] if steps else None


def restore(
    ckpt_dir: str,
    state_template: Any,
    step: Optional[int] = None,
    sharding_for: Optional[Callable[[str], Any]] = None,
) -> Tuple[Any, int, Dict]:
    """Restore onto the template's structure.  ``sharding_for(key)`` (if
    given) maps each leaf onto a device sharding — pass shardings built from
    the *current* mesh to re-shard elastically."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((path / "manifest.json").read_text())
    by_key = {e["key"]: e for e in manifest["leaves"]}
    leaves, treedef = _flatten_with_paths(state_template)
    new_leaves = []
    for key, tmpl in leaves:
        e = by_key[key]
        arr = np.load(path / e["file"])
        if str(arr.dtype) != e["dtype"]:  # byte-view round-trip (bf16/fp8)
            import ml_dtypes  # ships with jax

            arr = arr.view(np.dtype(getattr(ml_dtypes, e["dtype"], e["dtype"])))
        assert tuple(arr.shape) == tuple(tmpl.shape), (key, arr.shape, tmpl.shape)
        if sharding_for is not None:
            new_leaves.append(jax.device_put(arr, sharding_for(key)))
        else:
            new_leaves.append(jax.numpy.asarray(arr, dtype=tmpl.dtype))
    state = jax.tree_util.tree_unflatten(treedef, new_leaves)
    return state, manifest["step"], manifest["extra"]


def prune(ckpt_dir: str, keep: int = 3) -> None:
    base = Path(ckpt_dir)
    if not base.exists():
        return
    steps = sorted(
        p for p in base.iterdir() if p.is_dir() and p.name.startswith("step_")
    )
    for p in steps[:-keep]:
        shutil.rmtree(p)
