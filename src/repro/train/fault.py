"""Fault tolerance for 1000+-node runs: heartbeat/straggler monitoring and
the restart/elastic-reshard policy.

On real multi-host TPU pods each host runs the same SPMD program; failures
surface as missing heartbeats or collective timeouts.  The policy layer here
is host-agnostic (driven by step-duration samples + liveness callbacks) and
is exercised on CPU by the tests and the trainer with simulated failures —
the same code path a production launcher would call.

Design (matches the paper's scale story translated to pods):
* heartbeat: every worker stamps a monotonic step counter; the monitor flags
  workers > ``timeout`` behind the median.
* straggler mitigation: workers whose rolling step time exceeds
  ``straggler_factor`` × fleet median get flagged; the launcher's response is
  (1) re-route input shards away from them, (2) if persistent, treat as
  failed and trigger an elastic reshape.
* elastic reshape: pick the largest feasible mesh from the survivor count
  (power-of-two data axis, fixed model axis), restore the latest checkpoint
  onto it (checkpoint.restore is sharding-agnostic), and continue — the
  deterministic data pipeline replays from the exact step cursor.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple


@dataclass
class WorkerState:
    last_step: int = 0
    last_beat: float = 0.0
    step_times: deque = field(default_factory=lambda: deque(maxlen=16))
    alive: bool = True


class HeartbeatMonitor:
    def __init__(self, n_workers: int, timeout_s: float = 60.0, straggler_factor: float = 2.0):
        self.workers: Dict[int, WorkerState] = {i: WorkerState() for i in range(n_workers)}
        self.timeout_s = timeout_s
        self.straggler_factor = straggler_factor

    def beat(self, worker: int, step: int, now: Optional[float] = None) -> None:
        now = time.monotonic() if now is None else now
        w = self.workers[worker]
        if w.last_beat:
            w.step_times.append((now - w.last_beat) / max(step - w.last_step, 1))
        w.last_step, w.last_beat = step, now

    def _median_rate(self) -> float:
        rates = sorted(
            sum(w.step_times) / len(w.step_times)
            for w in self.workers.values()
            if w.alive and w.step_times
        )
        return rates[len(rates) // 2] if rates else 0.0

    def dead(self, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        return [
            i for i, w in self.workers.items()
            if w.alive and w.last_beat and now - w.last_beat > self.timeout_s
        ]

    def stragglers(self) -> List[int]:
        med = self._median_rate()
        if med <= 0:
            return []
        out = []
        for i, w in self.workers.items():
            if w.alive and w.step_times:
                mine = sum(w.step_times) / len(w.step_times)
                if mine > self.straggler_factor * med:
                    out.append(i)
        return out

    def mark_dead(self, worker: int) -> None:
        self.workers[worker].alive = False

    def alive_count(self) -> int:
        return sum(w.alive for w in self.workers.values())


def elastic_mesh_shape(survivors: int, model_axis: int = 16, pod_axis: int = 1) -> Tuple[int, ...]:
    """Largest power-of-two data axis that the survivor count supports, model
    axis fixed (TP re-sharding changes per-op layouts; DP scaling does not)."""
    per_pod = survivors // pod_axis
    data = 1
    while 2 * data * model_axis <= per_pod:
        data *= 2
    if data * model_axis < model_axis:
        raise RuntimeError(f"not enough survivors ({survivors}) for model axis {model_axis}")
    if pod_axis > 1:
        return (pod_axis, data, model_axis)
    return (data, model_axis)


@dataclass
class RestartPolicy:
    """What the launcher does per failure class."""
    max_restarts: int = 100
    restarts: int = 0

    def on_failure(self, monitor: HeartbeatMonitor, dead: List[int]) -> Dict:
        for d in dead:
            monitor.mark_dead(d)
        self.restarts += 1
        if self.restarts > self.max_restarts:
            raise RuntimeError("restart budget exhausted")
        shape = elastic_mesh_shape(monitor.alive_count())
        return {
            "action": "elastic_restart",
            "new_mesh_shape": shape,
            "resume": "latest_checkpoint + deterministic data cursor",
        }
