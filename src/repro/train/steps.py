"""jit-able step functions + their sharding trees.

``make_train_step`` builds the pjit'd update; ZeRO-1 (optimizer-state sharded
over the data axes) and int8 error-feedback gradient compression are RunFlags
levers.  These are the functions the multi-pod dry-run lowers.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.dist.sharding import MeshRules, param_specs
from repro.models.runtime import DEFAULT_FLAGS, RunFlags
from repro.models.transformer import forward, loss_fn
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update, schedule_for


def make_train_state(params: Any, opt_cfg: AdamWConfig) -> Dict[str, Any]:
    return {"params": params, "opt": adamw_init(params, opt_cfg), "step": jnp.zeros((), jnp.int32)}


def train_state_shape(cfg: ModelConfig, opt_cfg: AdamWConfig):
    from repro.models.transformer import init_params

    return jax.eval_shape(
        lambda: make_train_state(init_params(jax.random.key(0), cfg), opt_cfg)
    )


def zero1_spec(spec: P, shape, rules: MeshRules) -> P:
    """Additionally shard an optimizer-state leaf over the data axes (ZeRO-1).

    The first dimension not already sharded whose size divides dp gets the dp
    axes — the fp32 m/v/master tensors are the memory hog at scale.
    """
    parts = list(spec) + [None] * (len(shape) - len(spec))
    for i, (ax, size) in enumerate(zip(parts, shape)):
        if ax is None and size % rules.dp == 0 and size >= rules.dp:
            parts[i] = rules.dp_axes
            return P(*parts)
    return spec


def train_state_specs(cfg: ModelConfig, rules: MeshRules, opt_cfg: AdamWConfig, flags: RunFlags):
    shapes = train_state_shape(cfg, opt_cfg)
    pspecs = param_specs(shapes["params"], cfg, rules)

    def opt_leaf_specs(subtree_shapes):
        base = param_specs(subtree_shapes, cfg, rules)
        if not flags.zero1:
            return base
        return jax.tree_util.tree_map(
            lambda sp, sh: zero1_spec(sp, sh.shape, rules), base, subtree_shapes
        )

    ospecs = {
        "m": opt_leaf_specs(shapes["opt"]["m"]),
        "v": opt_leaf_specs(shapes["opt"]["v"]),
        "count": P(),
    }
    if "master" in shapes["opt"]:
        ospecs["master"] = opt_leaf_specs(shapes["opt"]["master"])
    return {"params": pspecs, "opt": ospecs, "step": P()}


def batch_specs_tree(batch_shapes: Dict[str, Any], rules: MeshRules) -> Dict[str, Any]:
    out = {}
    for k, v in batch_shapes.items():
        axes = rules.batch_axes(v.shape[0])
        out[k] = P(axes, *([None] * (len(v.shape) - 1)))
    return out


def make_train_step(
    cfg: ModelConfig,
    flags: RunFlags = DEFAULT_FLAGS,
    rules: Optional[MeshRules] = None,
    opt_cfg: AdamWConfig = AdamWConfig(),
    base_lr: float = 3e-4,
    total_steps: int = 10_000,
) -> Callable:
    sched = schedule_for(cfg, base_lr, total_steps)

    def grads_of(params, batch):
        def loss_wrap(p):
            return loss_fn(p, cfg, batch, flags, rules)

        return jax.value_and_grad(loss_wrap, has_aux=True)(params)

    def train_step(state, batch):
        k = flags.grad_accum
        if k > 1:
            # microbatch over the leading batch dim; fp32 grad accumulator
            def micro(carry, mb):
                acc, loss_acc = carry
                (loss, metrics), g = grads_of(state["params"], mb)
                acc = jax.tree_util.tree_map(
                    lambda a, gg: a + gg.astype(jnp.float32) / k, acc, g
                )
                return (acc, loss_acc + loss / k), metrics

            zeros = jax.tree_util.tree_map(
                lambda l: jnp.zeros(l.shape, jnp.float32), state["params"]
            )
            micro_batch = jax.tree_util.tree_map(
                lambda a: a.reshape((k, a.shape[0] // k) + a.shape[1:]), batch
            )
            (grads, loss), metrics_stack = jax.lax.scan(micro, (zeros, jnp.float32(0)), micro_batch)
            metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics_stack)
        else:
            (loss, metrics), grads = grads_of(state["params"], batch)
        lr = sched(state["step"])
        new_params, new_opt = adamw_update(grads, state["opt"], state["params"], opt_cfg, lr)
        new_state = {"params": new_params, "opt": new_opt, "step": state["step"] + 1}
        out_metrics = {"loss": loss, "lr": lr, **metrics}
        return new_state, out_metrics

    return train_step


def jit_train_step(cfg, rules: MeshRules, flags: RunFlags, opt_cfg=AdamWConfig(), donate: bool = True):
    step = make_train_step(cfg, flags, rules, opt_cfg)
    sspecs = train_state_specs(cfg, rules, opt_cfg, flags)
    mesh = rules.mesh
    to_sharding = lambda tree: jax.tree_util.tree_map(
        lambda sp: NamedSharding(mesh, sp), tree, is_leaf=lambda x: isinstance(x, P)
    )
    return partial(
        jax.jit,
        in_shardings=(to_sharding(sspecs), None),
        out_shardings=(to_sharding(sspecs), None),
        donate_argnums=(0,) if donate else (),
    )(step), sspecs
